//! Simulated MPI — the distributed-memory substrate (numerics side).
//!
//! The image has one core and no MPI, so rank-parallel execution is
//! simulated: a [`World`] holds all ranks' state in one address space and
//! executes them in lockstep *per communication phase*. This is a genuine
//! message-passing model, not a shortcut: sends and receives go through
//! per-destination mailboxes keyed by (src, dst, tag, communicator), and
//! the paper's deadlock-avoidance idiom — the `ISODD(k)` odd/even
//! communicator split of Code 1 that keeps two consecutive iterations'
//! collectives apart — is reproduced and property-tested.
//!
//! *Timing* is not modelled here (that is `simulator`); `simmpi` provides
//! bit-accurate multi-rank numerics: halo exchanges move real vector
//! planes, allreduces combine real partial sums, so multi-rank solver
//! convergence (including reduction-order effects) is real.

use std::collections::BTreeMap;

use crate::mesh::HaloMap;

/// Communicator id. The paper uses two (`MPIcommD[ISODD(k)]`) to overlap
/// collectives of consecutive iterations without tag collisions.
pub type Comm = usize;

/// Message tag (the paper's `MPItag + ISODD(k)`).
pub type Tag = u64;

#[derive(Debug, Clone, PartialEq)]
struct Message {
    src: usize,
    data: Vec<f64>,
}

/// Nonblocking request handle (mirrors MPI_Request + TAMPI_Iwait: the
/// request resolves when the matching message is consumed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    dst: usize,
    key: MsgKey,
    seq: u64,
}

type MsgKey = (usize, usize, Tag, Comm); // (src, dst, tag, comm)

/// All ranks' mailboxes. Ranks interact only through this structure.
#[derive(Debug, Default)]
pub struct World {
    nranks: usize,
    mailboxes: BTreeMap<MsgKey, Vec<Message>>,
    seq: u64,
    /// pending allreduce contributions per (comm, tag): rank -> value
    reductions: BTreeMap<(Comm, Tag), BTreeMap<usize, Vec<f64>>>,
    pub stats: WorldStats,
}

#[derive(Debug, Default, Clone)]
pub struct WorldStats {
    pub p2p_messages: u64,
    pub p2p_bytes: u64,
    pub allreduces: u64,
}

impl World {
    pub fn new(nranks: usize) -> Self {
        World {
            nranks,
            ..Default::default()
        }
    }

    pub fn nranks(&self) -> usize {
        self.nranks
    }

    /// Nonblocking send (MPI_Isend): the payload is buffered immediately
    /// (eager protocol — matches small halo planes).
    pub fn isend(&mut self, src: usize, dst: usize, tag: Tag, comm: Comm, data: Vec<f64>) -> Request {
        assert!(src < self.nranks && dst < self.nranks, "bad rank");
        let key = (src, dst, tag, comm);
        self.stats.p2p_messages += 1;
        self.stats.p2p_bytes += (data.len() * 8) as u64;
        self.mailboxes.entry(key).or_default().push(Message { src, data });
        self.seq += 1;
        Request {
            dst,
            key,
            seq: self.seq,
        }
    }

    /// Blocking receive (MPI_Recv after TAMPI_Iwait): pops the oldest
    /// matching message. Returns None if nothing is pending — callers in
    /// lockstep phases treat that as a deadlock bug, and tests assert it.
    pub fn recv(&mut self, src: usize, dst: usize, tag: Tag, comm: Comm) -> Option<Vec<f64>> {
        let key = (src, dst, tag, comm);
        let q = self.mailboxes.get_mut(&key)?;
        if q.is_empty() {
            return None;
        }
        Some(q.remove(0).data)
    }

    /// Number of undelivered messages (a clean phase ends at 0).
    pub fn in_flight(&self) -> usize {
        self.mailboxes.values().map(|q| q.len()).sum()
    }

    /// Contribute a local partial to an allreduce(SUM) on `comm`. When all
    /// ranks have contributed, returns the reduced vector to every caller
    /// via `try_complete_allreduce`.
    pub fn allreduce_contribute(&mut self, rank: usize, comm: Comm, tag: Tag, partial: Vec<f64>) {
        self.reductions
            .entry((comm, tag))
            .or_default()
            .insert(rank, partial);
    }

    /// Complete the allreduce if every rank contributed. The reduction
    /// order is deterministic (by rank) — matching MPI's fixed-topology
    /// reduction trees; *task-order* nondeterminism lives in taskrt where
    /// the paper locates it (§3.3), not here.
    pub fn try_complete_allreduce(&mut self, comm: Comm, tag: Tag) -> Option<Vec<f64>> {
        let parts = self.reductions.get(&(comm, tag))?;
        if parts.len() != self.nranks {
            return None;
        }
        let parts = self.reductions.remove(&(comm, tag)).unwrap();
        let len = parts.values().next().map(|v| v.len()).unwrap_or(0);
        let mut acc = vec![0.0; len];
        for (_rank, v) in parts {
            assert_eq!(v.len(), len, "ragged allreduce");
            for (a, x) in acc.iter_mut().zip(&v) {
                *a += x;
            }
        }
        self.stats.allreduces += 1;
        Some(acc)
    }

    /// Convenience synchronous allreduce for lockstep drivers: all ranks'
    /// partials in, reduced vector out.
    pub fn allreduce_sum(&mut self, comm: Comm, tag: Tag, partials: Vec<Vec<f64>>) -> Vec<f64> {
        assert_eq!(partials.len(), self.nranks);
        for (rank, p) in partials.into_iter().enumerate() {
            self.allreduce_contribute(rank, comm, tag, p);
        }
        self.try_complete_allreduce(comm, tag)
            .expect("all ranks contributed")
    }
}

/// One rank's halo exchange: post all receives conceptually, send all
/// planes, then deliver. The lockstep driver calls `post_sends` for every
/// rank first, then `complete_recvs` for every rank — the simulated
/// equivalent of Code 2's Irecv/Isend + TAMPI_Iwait tasks.
pub struct HaloExchange;

impl HaloExchange {
    /// Copy this rank's boundary planes into the mailboxes.
    pub fn post_sends(
        world: &mut World,
        rank: usize,
        halo: &HaloMap,
        x: &[f64],
        tag: Tag,
        comm: Comm,
    ) {
        for nb in &halo.neighbours {
            // paper Code 2: gather `elements_to_send` into a contiguous
            // buffer inside the send task
            let buf: Vec<f64> = nb.send.iter().map(|&i| x[i]).collect();
            world.isend(rank, nb.rank, tag, comm, buf);
        }
    }

    /// Receive every neighbour's plane into the extended vector.
    /// Returns false on missing message (deadlock — tests assert true).
    pub fn complete_recvs(
        world: &mut World,
        rank: usize,
        halo: &HaloMap,
        x_ext: &mut [f64],
        tag: Tag,
        comm: Comm,
    ) -> bool {
        for nb in &halo.neighbours {
            match world.recv(nb.rank, rank, tag, comm) {
                Some(data) => {
                    assert_eq!(data.len(), nb.recv_len);
                    x_ext[nb.recv_offset..nb.recv_offset + nb.recv_len].copy_from_slice(&data);
                }
                None => return false,
            }
        }
        true
    }
}

/// The paper's ISODD macro: alternate communicators/tags per iteration to
/// decouple consecutive iterations' communications.
#[inline]
pub fn isodd(k: usize) -> usize {
    k & 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{Grid3, Partition};
    use crate::util::proptest::forall;
    use crate::util::Rng;

    #[test]
    fn p2p_fifo_per_key() {
        let mut w = World::new(2);
        w.isend(0, 1, 5, 0, vec![1.0]);
        w.isend(0, 1, 5, 0, vec![2.0]);
        assert_eq!(w.recv(0, 1, 5, 0), Some(vec![1.0]));
        assert_eq!(w.recv(0, 1, 5, 0), Some(vec![2.0]));
        assert_eq!(w.recv(0, 1, 5, 0), None);
    }

    #[test]
    fn tags_and_comms_isolate() {
        let mut w = World::new(2);
        w.isend(0, 1, 1, 0, vec![1.0]);
        w.isend(0, 1, 2, 0, vec![2.0]);
        w.isend(0, 1, 1, 1, vec![3.0]);
        assert_eq!(w.recv(0, 1, 2, 0), Some(vec![2.0]));
        assert_eq!(w.recv(0, 1, 1, 1), Some(vec![3.0]));
        assert_eq!(w.recv(0, 1, 1, 0), Some(vec![1.0]));
        assert_eq!(w.in_flight(), 0);
    }

    #[test]
    fn allreduce_sums_over_ranks() {
        let mut w = World::new(4);
        let parts: Vec<Vec<f64>> = (0..4).map(|r| vec![r as f64, 1.0]).collect();
        let total = w.allreduce_sum(0, 0, parts);
        assert_eq!(total, vec![6.0, 4.0]);
        assert_eq!(w.stats.allreduces, 1);
    }

    #[test]
    fn allreduce_incomplete_returns_none() {
        let mut w = World::new(3);
        w.allreduce_contribute(0, 0, 7, vec![1.0]);
        w.allreduce_contribute(2, 0, 7, vec![1.0]);
        assert_eq!(w.try_complete_allreduce(0, 7), None);
        w.allreduce_contribute(1, 0, 7, vec![1.0]);
        assert_eq!(w.try_complete_allreduce(0, 7), Some(vec![3.0]));
    }

    #[test]
    fn halo_exchange_moves_boundary_planes() {
        let g = Grid3::new(2, 2, 9);
        let nranks = 3;
        let parts: Vec<Partition> = (0..nranks).map(|r| Partition::new(g, r, nranks)).collect();
        let mut w = World::new(nranks);
        // each rank's x = its rank id everywhere
        let xs: Vec<Vec<f64>> = parts
            .iter()
            .map(|p| {
                let mut v = vec![0.0; p.n_ext()];
                for e in v.iter_mut().take(p.n_local()) {
                    *e = p.rank as f64 + 1.0;
                }
                v
            })
            .collect();
        let mut xs = xs;
        for p in &parts {
            HaloExchange::post_sends(&mut w, p.rank, &p.halo_map(), &xs[p.rank], 0, 0);
        }
        for p in &parts {
            let hm = p.halo_map();
            let ok = HaloExchange::complete_recvs(&mut w, p.rank, &hm, &mut xs[p.rank], 0, 0);
            assert!(ok, "deadlock at rank {}", p.rank);
        }
        assert_eq!(w.in_flight(), 0);
        // rank 1 received rank 0's plane (value 1.0) then rank 2's (3.0)
        let p1 = &parts[1];
        let n = p1.n_local();
        let plane = g.plane();
        assert!(xs[1][n..n + plane].iter().all(|&v| v == 1.0));
        assert!(xs[1][n + plane..n + 2 * plane].iter().all(|&v| v == 3.0));
        // pad slot untouched
        assert_eq!(xs[1][p1.pad_slot()], 0.0);
    }

    #[test]
    fn isodd_communicators_prevent_cross_iteration_mixup() {
        // Two iterations' halo payloads in flight simultaneously: the
        // odd/even tag split must keep them separable in any recv order.
        let g = Grid3::new(2, 2, 4);
        let parts: Vec<Partition> = (0..2).map(|r| Partition::new(g, r, 2)).collect();
        let mut w = World::new(2);
        let mk = |val: f64, p: &Partition| {
            let mut v = vec![0.0; p.n_ext()];
            for e in v.iter_mut().take(p.n_local()) {
                *e = val;
            }
            v
        };
        // iteration k=0 sends (tag base+0), iteration k=1 sends (tag base+1)
        for (k, val) in [(0usize, 10.0), (1usize, 20.0)] {
            for p in &parts {
                let x = mk(val + p.rank as f64, p);
                HaloExchange::post_sends(&mut w, p.rank, &p.halo_map(), &x, isodd(k) as Tag, isodd(k));
            }
        }
        // receive iteration 1 first, then iteration 0 — no mixup
        for k in [1usize, 0] {
            for p in &parts {
                let mut x = mk(0.0, p);
                let ok =
                    HaloExchange::complete_recvs(&mut w, p.rank, &p.halo_map(), &mut x, isodd(k) as Tag, isodd(k));
                assert!(ok);
                let other = 1 - p.rank;
                let want = [10.0, 20.0][k] + other as f64;
                let n = p.n_local();
                assert!(x[n..n + g.plane()].iter().all(|&v| v == want), "k={k}");
            }
        }
    }

    #[test]
    fn property_allreduce_order_independent() {
        // Global sum must not depend on contribution order (MPI semantics:
        // fixed reduction tree) — we reduce by rank order internally.
        forall(
            404,
            100,
            |r, s| {
                let nranks = 2 + r.below(6);
                let len = 1 + r.below(4 * s.0.max(1));
                let vals: Vec<Vec<f64>> = (0..nranks)
                    .map(|_| (0..len).map(|_| r.normal()).collect())
                    .collect();
                let mut order: Vec<usize> = (0..nranks).collect();
                r.shuffle(&mut order);
                (vals, order)
            },
            |(vals, order)| {
                let nranks = vals.len();
                let mut w1 = World::new(nranks);
                for rank in 0..nranks {
                    w1.allreduce_contribute(rank, 0, 0, vals[rank].clone());
                }
                let a = w1.try_complete_allreduce(0, 0).unwrap();
                let mut w2 = World::new(nranks);
                for &rank in order {
                    w2.allreduce_contribute(rank, 0, 0, vals[rank].clone());
                }
                let b = w2.try_complete_allreduce(0, 0).unwrap();
                a == b
            },
        );
    }

    #[test]
    fn property_halo_roundtrip_any_world() {
        // For any grid/rank-count, a full exchange delivers every plane to
        // the right region and leaves nothing in flight.
        forall(
            505,
            60,
            |r, _| {
                let nz = 3 + r.below(12);
                let nranks = 1 + r.below(nz.min(5));
                let nx = 1 + r.below(4);
                let ny = 1 + r.below(4);
                (nx, ny, nz, nranks, Rng::new(r.next_u64()))
            },
            |&(nx, ny, nz, nranks, ref rng)| {
                let g = Grid3::new(nx, ny, nz);
                let parts: Vec<Partition> =
                    (0..nranks).map(|r| Partition::new(g, r, nranks)).collect();
                let mut rng = rng.clone();
                let mut w = World::new(nranks);
                let mut xs: Vec<Vec<f64>> = parts
                    .iter()
                    .map(|p| {
                        let mut v = vec![0.0; p.n_ext()];
                        for e in v.iter_mut().take(p.n_local()) {
                            *e = rng.normal();
                        }
                        v
                    })
                    .collect();
                let globals: Vec<Vec<f64>> = xs.iter().map(|x| x.clone()).collect();
                for p in &parts {
                    HaloExchange::post_sends(&mut w, p.rank, &p.halo_map(), &xs[p.rank], 3, 0);
                }
                for p in &parts {
                    let hm = p.halo_map();
                    if !HaloExchange::complete_recvs(&mut w, p.rank, &hm, &mut xs[p.rank], 3, 0) {
                        return false;
                    }
                }
                if w.in_flight() != 0 {
                    return false;
                }
                // verify via global indexing: each halo slot equals the
                // owner's value
                for p in &parts {
                    for grow in 0..g.n() {
                        if let Some(l) = p.local_of_global(grow) {
                            if l >= p.n_local() && l < p.pad_slot() {
                                // find owner rank + its local index
                                let owner = parts
                                    .iter()
                                    .find(|q| {
                                        q.local_of_global(grow)
                                            .map(|ol| ol < q.n_local())
                                            .unwrap_or(false)
                                    })
                                    .unwrap();
                                let ol = owner.local_of_global(grow).unwrap();
                                if xs[p.rank][l] != globals[owner.rank][ol] {
                                    return false;
                                }
                            }
                        }
                    }
                }
                true
            },
        );
    }
}
