//! Simulated MPI — the distributed-memory substrate (numerics side).
//!
//! Since the transport refactor this module is organised around the
//! [`Transport`] trait: the per-rank communication handle every solver
//! iteration loop is written against (post halo sends / blocking
//! receives, nonblocking allreduce contribution + wait, with the paper's
//! `ISODD(k)` odd/even communicator split preserved on top). Two
//! execution disciplines implement it, both living in [`hub`]:
//!
//!  * **lockstep** ([`TransportKind::Lockstep`]) — the bit-exact oracle.
//!    Rank bodies are strictly serialised: exactly one rank executes at
//!    any time, and control passes round-robin in rank order at every
//!    blocking communication call (the historical `World` behaviour,
//!    where the driver stepped all ranks per communication phase, now
//!    expressed as cooperative scheduling of the inverted per-rank
//!    loops).
//!  * **threaded** ([`TransportKind::Threaded`]) — each rank is a real
//!    OS thread owning its own `RankState` and shared-memory `Executor`,
//!    communicating through concurrent per-(src, dst, tag, comm)
//!    mailboxes (mutex + condvar) and the same fixed-order allreduce.
//!
//! **Determinism contract.** Message queues are FIFO per (src, dst, tag,
//! comm) key and sends are eager, so the payload a receive observes never
//! depends on scheduling; allreduce partials are folded by [`rank_fold`]
//! — one fixed reduction schedule over rank order, shared by both
//! disciplines (the fixed-topology reduction tree of MPI; bit-for-bit
//! the fold the old lockstep `World` used). Consequence: lockstep and
//! threaded runs produce *bitwise identical* convergence histories
//! (asserted by `tests/integration_exec.rs`). The §3.3 task-order
//! nondeterminism the paper studies stays where the paper locates it —
//! in the shared-memory task layer — not here.
//!
//! *Timing* is not modelled here (that is `simulator`); `simmpi`
//! provides bit-accurate multi-rank numerics: halo exchanges move real
//! vector planes, allreduces combine real partial sums, so multi-rank
//! solver convergence (including reduction-order effects) is real.

pub mod fault;
pub mod hub;

pub use fault::{Fault, FaultKind, FaultPlan};
pub use hub::{run_ranks, try_run_ranks, Hub, RankTransport};

use crate::mesh::HaloMap;

/// A structured transport-layer failure: which rank failed, in which
/// communication phase, and why. Raised instead of an opaque panic by
/// the hub's deadlock detectors and fault-injection aborts, and carried
/// up through [`try_run_ranks`] so callers can report it as a typed
/// [`crate::api::SolveError::TransportFailure`] instead of a process
/// abort.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransportFailure {
    /// The rank whose wait failed (for peer-abort propagation, the rank
    /// that *originated* the failure once `try_run_ranks` selected the
    /// primary).
    pub rank: usize,
    /// The communication phase that was blocked: "recv", "allreduce",
    /// "attach", or the fault-injection site.
    pub phase: String,
    /// Human-readable cause ("lockstep deadlock", "timeout",
    /// "injected abort", "a peer rank failed", ...).
    pub what: String,
}

impl std::fmt::Display for TransportFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "transport failure at rank {} during {}: {}",
            self.rank, self.phase, self.what
        )
    }
}

impl TransportFailure {
    /// True when this failure is only the echo of another rank's
    /// failure (the poisoned-hub abort every peer takes), as opposed to
    /// the originating fault. `try_run_ranks` prefers non-peer failures
    /// when selecting the primary cause to report.
    pub fn is_peer_echo(&self) -> bool {
        self.what.contains("peer rank failed")
    }
}

/// Communicator id. The paper uses two (`MPIcommD[ISODD(k)]`) to overlap
/// collectives of consecutive iterations without tag collisions.
pub type Comm = usize;

/// Message tag (the paper's `MPItag + ISODD(k)`).
pub type Tag = u64;

/// Mailbox key: (src, dst, tag, comm).
pub type MsgKey = (usize, usize, Tag, Comm);

/// Which transport discipline executes the per-rank solver loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Strictly serialised rank execution (the bit-exact oracle).
    Lockstep,
    /// One OS thread per rank, genuinely concurrent.
    Threaded,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "lockstep" => TransportKind::Lockstep,
            "threaded" | "threads" => TransportKind::Threaded,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Lockstep => "lockstep",
            TransportKind::Threaded => "threaded",
        }
    }
}

/// Widest allreduce payload any solver posts: every collective in the
/// method loops is a scalar or a fused pair (ω's numerator/denominator,
/// αn with β), so payloads fit inline — no heap traffic per collective.
pub const MAX_REDUCE_LEN: usize = 2;

/// Inline allreduce payload (at most [`MAX_REDUCE_LEN`] lanes). `Copy`,
/// so posting a contribution and taking a result moves a couple of
/// machine words instead of allocating a `Vec<f64>` per collective —
/// part of the zero-allocation steady state (DESIGN.md §7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Payload {
    vals: [f64; MAX_REDUCE_LEN],
    len: usize,
    /// Duplicate-fold checksum lane (ABFT-style, DESIGN.md §13). Sealed
    /// by recovery-aware callers to the lane sum *before* posting; the
    /// fold accumulates it alongside the data lanes, so on a clean round
    /// the folded `check` equals the sum of the folded lanes (both are
    /// the same linear combination of the same rank contributions,
    /// reassociated). A lane corrupted *after* sealing breaks the
    /// identity and is detected at the consumer. Always carried, never
    /// read unless a caller sealed it — the default path is unchanged.
    check: f64,
}

impl Payload {
    /// One-lane payload (scalar allreduce).
    pub fn scalar(v: f64) -> Self {
        Payload {
            vals: [v, 0.0],
            len: 1,
            check: 0.0,
        }
    }

    /// Two-lane payload (fused pair allreduce).
    pub fn pair(a: f64, b: f64) -> Self {
        Payload {
            vals: [a, b],
            len: 2,
            check: 0.0,
        }
    }

    /// Payload from a slice of at most [`MAX_REDUCE_LEN`] lanes.
    pub fn from_slice(s: &[f64]) -> Self {
        assert!(
            s.len() <= MAX_REDUCE_LEN,
            "allreduce payload wider than MAX_REDUCE_LEN"
        );
        let mut vals = [0.0; MAX_REDUCE_LEN];
        vals[..s.len()].copy_from_slice(s);
        Payload {
            vals,
            len: s.len(),
            check: 0.0,
        }
    }

    /// All-zero payload of `len` lanes — the fold identity
    /// [`rank_fold`] accumulates onto.
    pub fn zeros(len: usize) -> Self {
        assert!(len <= MAX_REDUCE_LEN, "allreduce payload too wide");
        Payload {
            vals: [0.0; MAX_REDUCE_LEN],
            len,
            check: 0.0,
        }
    }

    /// Element-wise `self += p` — one step of the [`rank_fold`]
    /// accumulation schedule. The checksum lane folds with the data
    /// lanes so the sealed-sum identity survives the reduction.
    pub fn accumulate(&mut self, p: &Payload) {
        assert_eq!(p.len(), self.len, "ragged allreduce");
        for i in 0..self.len {
            self.vals[i] += p.vals[i];
        }
        self.check += p.check;
    }

    /// Seal the checksum lane to the current lane sum. Call immediately
    /// before posting the contribution; any later lane mutation (an
    /// injected or real bit-flip) breaks `check == Σ lanes` at the
    /// consumer.
    pub fn seal(&mut self) {
        self.check = self.vals[..self.len].iter().sum();
    }

    /// The folded checksum lane (meaningful only if every contributor
    /// sealed).
    pub fn check(&self) -> f64 {
        self.check
    }

    /// Checksum drift of a folded payload: `|check − Σ lanes|`, with NaN
    /// anywhere reported as infinite drift. Zero-ish (fold reassociation
    /// rounding only) on a clean round where every rank sealed.
    pub fn check_drift(&self) -> f64 {
        let sum: f64 = self.vals[..self.len].iter().sum();
        let drift = (self.check - sum).abs();
        if drift.is_nan() {
            f64::INFINITY
        } else {
            drift
        }
    }

    /// Corrupt every data lane to NaN *in place*, leaving the checksum
    /// lane untouched — models a fault that hits the payload after the
    /// contributor sealed it (the hub's `corrupt-allreduce` injection).
    pub fn corrupt_lanes_nan(&mut self) {
        for v in &mut self.vals[..self.len] {
            *v = f64::NAN;
        }
    }

    /// Skew every data lane by a finite relative factor *in place*,
    /// leaving the checksum lane untouched — models a silent (finite)
    /// corruption that no non-finite guard can see.
    pub fn skew_lanes(&mut self, rel: f64) {
        for v in &mut self.vals[..self.len] {
            *v *= 1.0 + rel;
        }
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.vals[..self.len]
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::ops::Index<usize> for Payload {
    type Output = f64;

    fn index(&self, i: usize) -> &f64 {
        &self.as_slice()[i]
    }
}

/// Per-rank communication handle. Solver iteration loops run *per rank*
/// against this trait; the hub behind it decides scheduling (lockstep
/// oracle vs concurrent threads) without ever changing the numbers.
pub trait Transport {
    fn rank(&self) -> usize;

    fn nranks(&self) -> usize;

    /// Nonblocking eager send (MPI_Isend): the payload is buffered
    /// immediately — matches small halo planes. The transport copies
    /// `data` into its own (recycled) buffer, so the caller's staging
    /// buffer can be reused for the next neighbour right away.
    fn send(&mut self, dst: usize, tag: Tag, comm: Comm, data: &[f64]);

    /// Blocking receive (MPI_Recv after TAMPI_Iwait): pops the oldest
    /// matching message, waiting for it if necessary. A cyclic wait is a
    /// deadlock bug and panics (lockstep detects the cycle, threaded
    /// times out). Allocates the returned vector — tests and diagnostics
    /// use this; the solver hot path uses [`Transport::recv_into`].
    fn recv(&mut self, src: usize, tag: Tag, comm: Comm) -> Vec<f64>;

    /// Blocking receive straight into a caller buffer (the halo region
    /// of an extended vector). The message length must equal `out.len()`
    /// — a mismatch is a protocol bug and panics. The hub recycles the
    /// message buffer, so the steady state allocates nothing.
    fn recv_into(&mut self, src: usize, tag: Tag, comm: Comm, out: &mut [f64]);

    /// Nonblocking allreduce(SUM) contribution (MPI_Iallreduce post).
    /// Repeated use of the same (comm, tag) opens a new round each time;
    /// rounds complete in contribution order per rank.
    fn allreduce_start(&mut self, comm: Comm, tag: Tag, partial: Payload);

    /// Complete the oldest pending allreduce on (comm, tag) started by
    /// this rank, blocking until every rank contributed. The reduction
    /// order is [`rank_fold`] — fixed, rank-count-deterministic.
    fn allreduce_wait(&mut self, comm: Comm, tag: Tag) -> Payload;

    /// Overlap-effectiveness accounting: the solver reports how many
    /// interior rows it scheduled ahead of this phase's receive
    /// completion (rows of useful work available while the messages were
    /// in flight — plan-derived, so an upper bound: a straggler chunk
    /// claimed after the receives completed still counts). Lands in
    /// [`WorldStats::overlapped_rows`]; default no-op so test transports
    /// need not care.
    fn record_overlap(&mut self, _rows: u64) {}

    /// Blocking allreduce(SUM) — contribution + wait.
    fn allreduce(&mut self, comm: Comm, tag: Tag, partial: Payload) -> Payload {
        self.allreduce_start(comm, tag, partial);
        self.allreduce_wait(comm, tag)
    }
}

/// Communication statistics of one run, plus the concurrency accounting
/// the transport refactor's acceptance criteria rest on.
#[derive(Debug, Default, Clone)]
pub struct WorldStats {
    pub p2p_messages: u64,
    pub p2p_bytes: u64,
    pub allreduces: u64,
    /// Distinct OS threads that executed rank bodies. Under the threaded
    /// transport a startup barrier guarantees all of them exist
    /// concurrently before any body runs, so `rank_threads == nranks` is
    /// the deterministic thread-id accounting of real rank concurrency.
    pub rank_threads: usize,
    /// Maximum number of rank bodies *observed* executing simultaneously
    /// (parked waits excluded). Exactly 1 under lockstep — the
    /// serialisation invariant that makes it the oracle. Under the
    /// threaded transport this is an honest scheduler-dependent
    /// observation (typically the rank count, at least 1), not a value
    /// true by construction.
    pub max_concurrent_ranks: usize,
    /// Total interior rows scheduled ahead of the halo receives (between
    /// `Ops::exchange_start` and `Ops::exchange_finish`), summed over
    /// all ranks and iterations — the overlap-effectiveness gauge of the
    /// interior/boundary split. Plan-derived (each overlapped exchange
    /// credits its whole interior range), so it is an upper bound on the
    /// rows genuinely computed while messages were in flight. 0 when
    /// `--overlap off` or single-rank.
    pub overlapped_rows: u64,
}

/// The fixed allreduce reduction schedule shared by every transport
/// discipline: a deterministic chain over rank order (the degenerate
/// fixed reduction tree — MPI's fixed-topology reduction applied to a
/// linear topology, and bit-for-bit the fold the pre-refactor lockstep
/// `World` used). Rank-count-deterministic and schedule-independent:
/// this one function is why `--transport lockstep` and `--transport
/// threaded` produce bitwise identical convergence histories. Operates
/// on inline payloads, so folding never allocates.
pub fn rank_fold(parts: &[Payload]) -> Payload {
    rank_fold_iter(parts.iter().copied())
}

/// [`rank_fold`] over any payload iterator in iteration order — the
/// form the hub uses to fold contributions straight out of their
/// `Option` slots without materialising a slice. This is the single
/// authority for the fold schedule: same `0.0` identity, same
/// element-wise accumulation order, bit-for-bit.
pub fn rank_fold_iter(parts: impl Iterator<Item = Payload>) -> Payload {
    let mut parts = parts.peekable();
    let len = parts.peek().map(|p| p.len()).unwrap_or(0);
    let mut acc = Payload::zeros(len);
    for p in parts {
        acc.accumulate(&p);
    }
    acc
}

/// One rank's halo exchange over a [`Transport`]: gather each boundary
/// plane into a contiguous buffer and send (paper Code 2's
/// `elements_to_send`), then receive every neighbour's plane into the
/// extended vector. Receives block until the neighbour's send arrives.
pub struct HaloExchange;

impl HaloExchange {
    /// Copy this rank's boundary planes into the neighbours' mailboxes.
    /// `stage` is the caller's reusable gather buffer (one plane at a
    /// time) — the transport copies it into a recycled hub buffer, so
    /// the steady state allocates nothing on either side.
    pub fn post_sends(
        tp: &mut dyn Transport,
        halo: &HaloMap,
        x: &[f64],
        tag: Tag,
        comm: Comm,
        stage: &mut Vec<f64>,
    ) {
        for nb in &halo.neighbours {
            stage.clear();
            stage.extend(nb.send.iter().map(|&i| x[i]));
            tp.send(nb.rank, tag, comm, stage);
        }
    }

    /// Receive every neighbour's plane straight into the extended vector
    /// (blocking; a missing message is a deadlock and panics in the hub;
    /// a length mismatch panics in `recv_into`).
    pub fn complete_recvs(
        tp: &mut dyn Transport,
        halo: &HaloMap,
        x_ext: &mut [f64],
        tag: Tag,
        comm: Comm,
    ) {
        for nb in &halo.neighbours {
            tp.recv_into(
                nb.rank,
                tag,
                comm,
                &mut x_ext[nb.recv_offset..nb.recv_offset + nb.recv_len],
            );
        }
    }
}

/// The paper's ISODD macro: alternate communicators/tags per iteration to
/// decouple consecutive iterations' communications.
#[inline]
pub fn isodd(k: usize) -> usize {
    k & 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::{Grid3, Partition};
    use crate::util::proptest::forall;
    use crate::util::Rng;

    /// Run one closure per rank over a fresh hub and return (results,
    /// stats). Mirrors what the solver runner does.
    fn per_rank<R: Send>(
        kind: TransportKind,
        nranks: usize,
        body: impl Fn(&mut RankTransport) -> R + Sync,
    ) -> (Vec<R>, WorldStats) {
        let body = &body;
        let bodies: Vec<Box<dyn FnOnce(&mut RankTransport) -> R + Send + '_>> = (0..nranks)
            .map(|_| {
                Box::new(move |tp: &mut RankTransport| body(tp))
                    as Box<dyn FnOnce(&mut RankTransport) -> R + Send + '_>
            })
            .collect();
        run_ranks(kind, bodies)
    }

    fn both_kinds() -> [TransportKind; 2] {
        [TransportKind::Lockstep, TransportKind::Threaded]
    }

    #[test]
    fn p2p_fifo_per_key() {
        for kind in both_kinds() {
            let (got, stats) = per_rank(kind, 2, |tp| {
                if tp.rank() == 0 {
                    tp.send(1, 5, 0, &[1.0]);
                    tp.send(1, 5, 0, &[2.0]);
                    Vec::new()
                } else {
                    vec![tp.recv(0, 5, 0), tp.recv(0, 5, 0)]
                }
            });
            assert_eq!(got[1], vec![vec![1.0], vec![2.0]], "{kind:?}");
            assert_eq!(stats.p2p_messages, 2);
            assert_eq!(stats.p2p_bytes, 16);
        }
    }

    #[test]
    fn tags_and_comms_isolate() {
        for kind in both_kinds() {
            let (got, _) = per_rank(kind, 2, |tp| {
                if tp.rank() == 0 {
                    tp.send(1, 1, 0, &[1.0]);
                    tp.send(1, 2, 0, &[2.0]);
                    tp.send(1, 1, 1, &[3.0]);
                    Vec::new()
                } else {
                    // receive in a different order than sent
                    vec![tp.recv(0, 2, 0), tp.recv(0, 1, 1), tp.recv(0, 1, 0)]
                }
            });
            assert_eq!(got[1], vec![vec![2.0], vec![3.0], vec![1.0]], "{kind:?}");
        }
    }

    #[test]
    fn allreduce_sums_over_ranks() {
        for kind in both_kinds() {
            let (got, stats) = per_rank(kind, 4, |tp| {
                tp.allreduce(0, 0, Payload::pair(tp.rank() as f64, 1.0))
            });
            for v in got {
                assert_eq!(v.as_slice(), &[6.0, 4.0], "{kind:?}");
            }
            assert_eq!(stats.allreduces, 1);
        }
    }

    #[test]
    fn allreduce_rounds_keep_reused_tags_apart() {
        // The ISODD split reuses (comm, tag) every second iteration; a
        // rank may race two rounds ahead before a peer consumed round 0.
        for kind in both_kinds() {
            let (got, stats) = per_rank(kind, 3, |tp| {
                let r = tp.rank() as f64;
                let a = tp.allreduce(0, 7, Payload::scalar(r));
                let b = tp.allreduce(0, 7, Payload::scalar(10.0 * (r + 1.0)));
                (a, b)
            });
            for (a, b) in got {
                assert_eq!(a.as_slice(), &[3.0], "{kind:?}");
                assert_eq!(b.as_slice(), &[60.0], "{kind:?}");
            }
            assert_eq!(stats.allreduces, 2);
        }
    }

    #[test]
    fn nonblocking_allreduce_overlaps_p2p() {
        for kind in both_kinds() {
            let (got, _) = per_rank(kind, 2, |tp| {
                let me = tp.rank();
                tp.allreduce_start(1, 9, Payload::scalar(1.0 + me as f64));
                // p2p traffic between the contribution and the wait
                tp.send(1 - me, 0, 0, &[me as f64]);
                let mut msg = [0.0];
                tp.recv_into(1 - me, 0, 0, &mut msg);
                let sum = tp.allreduce_wait(1, 9);
                (msg[0], sum)
            });
            for (rank, (msg, sum)) in got.into_iter().enumerate() {
                assert_eq!(msg, (1 - rank) as f64, "{kind:?}");
                assert_eq!(sum.as_slice(), &[3.0], "{kind:?}");
            }
        }
    }

    #[test]
    fn rank_fold_is_fixed_and_matches_sum() {
        let parts: Vec<Payload> = (0..5).map(|r| Payload::pair(r as f64 * 0.5, 1.0)).collect();
        let a = rank_fold(&parts);
        assert_eq!(a.as_slice(), &[5.0, 5.0]);
        // determinism: same input, same bits
        let b = rank_fold(&parts);
        assert_eq!(a[0].to_bits(), b[0].to_bits());
        assert!(rank_fold(&[]).is_empty());
    }

    #[test]
    fn payload_shapes_roundtrip() {
        assert_eq!(Payload::scalar(2.5).as_slice(), &[2.5]);
        assert_eq!(Payload::pair(1.0, -2.0).as_slice(), &[1.0, -2.0]);
        let p = Payload::from_slice(&[4.0]);
        assert_eq!(p.len(), 1);
        assert!(!p.is_empty());
        assert_eq!(p[0], 4.0);
    }

    #[test]
    fn lockstep_serialises_threaded_runs_concurrent_threads() {
        let (_, s) = per_rank(TransportKind::Lockstep, 4, |tp| {
            tp.allreduce(0, 0, Payload::scalar(1.0))
        });
        assert_eq!(s.max_concurrent_ranks, 1, "lockstep must serialise");
        assert_eq!(s.rank_threads, 4);
        let (_, s) = per_rank(TransportKind::Threaded, 4, |tp| {
            tp.allreduce(0, 0, Payload::scalar(1.0))
        });
        // thread-id accounting: four distinct OS threads ran bodies, all
        // alive concurrently (startup barrier); the executing-overlap
        // gauge is an honest scheduler-dependent observation (>= 1).
        assert_eq!(s.rank_threads, 4);
        assert!(s.max_concurrent_ranks >= 1);
    }

    #[test]
    fn lockstep_detects_deadlock() {
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            per_rank(TransportKind::Lockstep, 2, |tp| {
                // both ranks receive a message nobody sends
                tp.recv(1 - tp.rank(), 99, 0)
            })
        }));
        assert!(out.is_err(), "cyclic wait must panic");
    }

    #[test]
    fn halo_exchange_moves_boundary_planes() {
        let g = Grid3::new(2, 2, 9);
        let nranks = 3;
        for kind in both_kinds() {
            let (xs, _) = per_rank(kind, nranks, |tp| {
                let p = Partition::new(g, tp.rank(), nranks);
                let mut x = vec![0.0; p.n_ext()];
                for e in x.iter_mut().take(p.n_local()) {
                    *e = p.rank as f64 + 1.0;
                }
                let hm = p.halo_map();
                let mut stage = Vec::new();
                HaloExchange::post_sends(tp, &hm, &x, 0, 0, &mut stage);
                HaloExchange::complete_recvs(tp, &hm, &mut x, 0, 0);
                x
            });
            // rank 1 received rank 0's plane (value 1.0) then rank 2's (3.0)
            let p1 = Partition::new(g, 1, nranks);
            let n = p1.n_local();
            let plane = g.plane();
            assert!(xs[1][n..n + plane].iter().all(|&v| v == 1.0), "{kind:?}");
            assert!(
                xs[1][n + plane..n + 2 * plane].iter().all(|&v| v == 3.0),
                "{kind:?}"
            );
            // pad slot untouched
            assert_eq!(xs[1][p1.pad_slot()], 0.0);
        }
    }

    #[test]
    fn isodd_communicators_prevent_cross_iteration_mixup() {
        // Two iterations' halo payloads in flight simultaneously: the
        // odd/even tag split must keep them separable in any recv order.
        let g = Grid3::new(2, 2, 4);
        for kind in both_kinds() {
            let (ok, _) = per_rank(kind, 2, |tp| {
                let p = Partition::new(g, tp.rank(), 2);
                let mk = |val: f64| {
                    let mut v = vec![0.0; p.n_ext()];
                    for e in v.iter_mut().take(p.n_local()) {
                        *e = val;
                    }
                    v
                };
                // iteration k=0 sends (tag base+0), k=1 sends (tag base+1)
                let mut stage = Vec::new();
                for (k, val) in [(0usize, 10.0), (1usize, 20.0)] {
                    let x = mk(val + p.rank as f64);
                    HaloExchange::post_sends(
                        tp,
                        &p.halo_map(),
                        &x,
                        isodd(k) as Tag,
                        isodd(k),
                        &mut stage,
                    );
                }
                // receive iteration 1 first, then iteration 0 — no mixup
                let mut good = true;
                for k in [1usize, 0] {
                    let mut x = mk(0.0);
                    HaloExchange::complete_recvs(
                        tp,
                        &p.halo_map(),
                        &mut x,
                        isodd(k) as Tag,
                        isodd(k),
                    );
                    let other = 1 - p.rank;
                    let want = [10.0, 20.0][k] + other as f64;
                    let n = p.n_local();
                    good &= x[n..n + g.plane()].iter().all(|&v| v == want);
                }
                good
            });
            assert!(ok.into_iter().all(|b| b), "{kind:?}");
        }
    }

    #[test]
    fn property_allreduce_order_independent() {
        // Global sum must not depend on contribution arrival order (MPI
        // semantics: fixed reduction schedule) — rank_fold reduces in
        // rank order no matter who contributed last.
        forall(
            404,
            40,
            |r, _| {
                let nranks = 2 + r.below(6);
                let len = 1 + r.below(MAX_REDUCE_LEN);
                let vals: Vec<Payload> = (0..nranks)
                    .map(|_| {
                        let lanes: Vec<f64> = (0..len).map(|_| r.normal()).collect();
                        Payload::from_slice(&lanes)
                    })
                    .collect();
                vals
            },
            |vals| {
                let nranks = vals.len();
                let direct = rank_fold(vals);
                for kind in both_kinds() {
                    let vals = &vals;
                    let (got, _) =
                        per_rank(kind, nranks, move |tp| tp.allreduce(0, 0, vals[tp.rank()]));
                    for v in got {
                        if v.as_slice()
                            .iter()
                            .zip(direct.as_slice())
                            .any(|(a, b)| a.to_bits() != b.to_bits())
                        {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    #[test]
    fn property_halo_roundtrip_any_world() {
        // For any grid/rank-count, a full exchange delivers every plane
        // to the right region, on both transports.
        forall(
            505,
            30,
            |r, _| {
                let nz = 3 + r.below(12);
                let nranks = 1 + r.below(nz.min(5));
                let nx = 1 + r.below(4);
                let ny = 1 + r.below(4);
                (nx, ny, nz, nranks, r.next_u64())
            },
            |&(nx, ny, nz, nranks, seed)| {
                let g = Grid3::new(nx, ny, nz);
                let parts: Vec<Partition> =
                    (0..nranks).map(|r| Partition::new(g, r, nranks)).collect();
                // deterministic per-rank fill, derived from the seed
                let fill = |rank: usize| {
                    let p = &parts[rank];
                    let mut rng = Rng::new(seed).substream(rank as u64);
                    let mut v = vec![0.0; p.n_ext()];
                    for e in v.iter_mut().take(p.n_local()) {
                        *e = rng.normal();
                    }
                    v
                };
                for kind in both_kinds() {
                    let parts = &parts;
                    let fill = &fill;
                    let (xs, _) = per_rank(kind, nranks, move |tp| {
                        let p = &parts[tp.rank()];
                        let mut x = fill(tp.rank());
                        let hm = p.halo_map();
                        let mut stage = Vec::new();
                        HaloExchange::post_sends(tp, &hm, &x, 3, 0, &mut stage);
                        HaloExchange::complete_recvs(tp, &hm, &mut x, 3, 0);
                        x
                    });
                    let globals: Vec<Vec<f64>> = (0..nranks).map(fill).collect();
                    // verify via global indexing: each halo slot equals
                    // the owner's value
                    for p in parts {
                        for grow in 0..g.n() {
                            if let Some(l) = p.local_of_global(grow) {
                                if l >= p.n_local() && l < p.pad_slot() {
                                    let owner = parts
                                        .iter()
                                        .find(|q| {
                                            q.local_of_global(grow)
                                                .map(|ol| ol < q.n_local())
                                                .unwrap_or(false)
                                        })
                                        .unwrap();
                                    let ol = owner.local_of_global(grow).unwrap();
                                    if xs[p.rank][l] != globals[owner.rank][ol] {
                                        return false;
                                    }
                                }
                            }
                        }
                    }
                }
                true
            },
        );
    }
}
