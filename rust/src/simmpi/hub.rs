//! The transport hub: one shared mailbox/reduction state per run, plus
//! the per-rank [`RankTransport`] handles the solver loops talk to.
//!
//! Both transport disciplines live here, sharing every data structure
//! and differing only in their wait/scheduling policy:
//!
//!  * **Lockstep** — the bit-exact oracle. A turn baton serialises rank
//!    bodies: a rank executes (compute *and* communication) only while it
//!    holds the turn, and yields it round-robin at every blocking call
//!    that cannot complete. Parked OS threads are merely the suspension
//!    mechanism for the inverted per-rank loops; at most one rank makes
//!    progress at any instant, which `WorldStats::max_concurrent_ranks
//!    == 1` asserts. A full turn cycle in which every rank declines to
//!    run is a communication deadlock and panics (the moral equivalent
//!    of the old `World::recv -> None`).
//!  * **Threaded** — real hybrid execution: every rank thread runs
//!    freely, blocking waits park on the condvar, and a startup barrier
//!    guarantees all rank threads exist concurrently before any body
//!    runs (the deterministic basis of the `rank_threads` accounting;
//!    `max_concurrent_ranks` then honestly samples how many bodies were
//!    observed executing at once). A wait that exceeds the deadlock
//!    timeout panics instead of hanging the test suite.
//!
//! Numbers never depend on the discipline: payloads are FIFO per
//! (src, dst, tag, comm) key, and allreduce partials fold via
//! [`super::rank_fold`] after all of them exist (see the determinism
//! contract in the module docs).

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::ThreadId;
use std::time::Duration;

use super::{rank_fold_iter, Comm, MsgKey, Payload, Tag, Transport, TransportKind, WorldStats};

/// One in-flight allreduce round on a (comm, tag) key. Rounds exist
/// because the ISODD split reuses keys every second iteration while a
/// fast rank may already be two allreduces ahead of a slow one.
/// Contributions and results are inline [`Payload`]s and finished rounds
/// return to `HubState::spare_rounds`, so the steady state recycles one
/// small struct per collective instead of allocating fresh vectors.
#[derive(Default)]
struct Round {
    parts: Vec<Option<Payload>>,
    nparts: usize,
    result: Option<Payload>,
    taken: Vec<bool>,
    ntaken: usize,
}

impl Round {
    /// Prepare a (possibly recycled) round for `nranks` contributions.
    fn reset(&mut self, nranks: usize) {
        self.parts.clear();
        self.parts.resize(nranks, None);
        self.nparts = 0;
        self.result = None;
        self.taken.clear();
        self.taken.resize(nranks, false);
        self.ntaken = 0;
    }
}

/// Key of one in-flight reduction: (comm, tag, round index).
type ReduceKey = (Comm, Tag, u64);

struct HubState {
    mailboxes: BTreeMap<MsgKey, VecDeque<Vec<f64>>>,
    /// In-flight reductions. A linear scan: at most a couple of rounds
    /// are ever open at once (the ISODD window), and the Vec keeps its
    /// capacity across rounds where a tree would churn nodes.
    reductions: Vec<(ReduceKey, Round)>,
    /// Recycled message payload buffers (capacity-preserving): `send`
    /// pops one, `recv_into` pushes the consumed buffer back.
    spare_bufs: Vec<Vec<f64>>,
    /// Recycled reduction rounds.
    spare_rounds: Vec<Round>,
    stats: WorldStats,
    thread_ids: HashSet<ThreadId>,
    /// Lockstep: the rank currently allowed to execute.
    turn: usize,
    finished: Vec<bool>,
    /// Ranks that have attached (threaded startup barrier).
    live: usize,
    /// Rank bodies currently executing (not parked in a wait).
    running: usize,
    /// Consecutive turn yields without any communication progress
    /// (lockstep deadlock detector).
    idle: usize,
    /// A rank panicked (or a deadlock was detected): everyone aborts.
    poisoned: bool,
}

/// Shared transport state for one `run_ranks` invocation.
pub struct Hub {
    state: Mutex<HubState>,
    cv: Condvar,
    kind: TransportKind,
    nranks: usize,
    /// Threaded blocking-wait bound; a genuine solve never comes close,
    /// so exceeding it is reported as a deadlock.
    deadlock_timeout: Duration,
}

impl Hub {
    pub fn new(nranks: usize, kind: TransportKind) -> Self {
        assert!(nranks > 0, "empty world");
        Hub {
            state: Mutex::new(HubState {
                mailboxes: BTreeMap::new(),
                reductions: Vec::new(),
                spare_bufs: Vec::new(),
                spare_rounds: Vec::new(),
                stats: WorldStats::default(),
                thread_ids: HashSet::new(),
                turn: 0,
                finished: vec![false; nranks],
                live: 0,
                running: 0,
                idle: 0,
                poisoned: false,
            }),
            cv: Condvar::new(),
            kind,
            nranks,
            deadlock_timeout: Duration::from_secs(30),
        }
    }

    pub fn kind(&self) -> TransportKind {
        self.kind
    }

    pub fn nranks(&self) -> usize {
        self.nranks
    }

    /// Communication statistics so far (final after the scope joined).
    pub fn stats(&self) -> WorldStats {
        let st = self.state.lock().unwrap();
        let mut s = st.stats.clone();
        s.rank_threads = st.thread_ids.len();
        s
    }

    /// Abort the run: wake every parked rank into a panic.
    fn poison(&self) {
        let mut st = self.state.lock().unwrap();
        st.poisoned = true;
        self.cv.notify_all();
    }
}

/// Pass the lockstep turn to the next unfinished rank (round-robin).
fn advance_turn(st: &mut HubState, nranks: usize) {
    for step in 1..=nranks {
        let cand = (st.turn + step) % nranks;
        if !st.finished[cand] {
            st.turn = cand;
            return;
        }
    }
    // everyone finished: leave the turn where it is
}

/// Per-rank communication handle (the `Transport` implementation).
pub struct RankTransport {
    hub: Arc<Hub>,
    rank: usize,
    /// Next round index per (comm, tag) this rank will contribute to.
    ar_next: BTreeMap<(Comm, Tag), u64>,
    /// Rounds contributed but not yet waited on, oldest first.
    ar_pending: BTreeMap<(Comm, Tag), VecDeque<u64>>,
    /// Overlap-effectiveness rows accumulated rank-locally
    /// (`Transport::record_overlap`) and flushed into the hub stats once
    /// at [`RankTransport::finish`] — the hot path never takes the hub
    /// lock just to bump this counter.
    overlap_rows: u64,
}

impl RankTransport {
    fn new(hub: Arc<Hub>, rank: usize) -> Self {
        assert!(rank < hub.nranks, "bad rank");
        RankTransport {
            hub,
            rank,
            ar_next: BTreeMap::new(),
            ar_pending: BTreeMap::new(),
            overlap_rows: 0,
        }
    }

    /// Register this rank's thread and enter the scheduling discipline:
    /// lockstep ranks wait for the turn baton, threaded ranks pass a
    /// startup barrier that releases all of them at once (the observed
    /// cross-rank overlap the acceptance criteria ask for).
    fn attach(&self) {
        let hub = &*self.hub;
        let mut st = hub.state.lock().unwrap();
        st.thread_ids.insert(std::thread::current().id());
        st.live += 1;
        hub.cv.notify_all();
        match hub.kind {
            TransportKind::Threaded => {
                // startup barrier: all rank threads must exist before any
                // body runs (the rendezvous behind `rank_threads`). The
                // running gauge starts only *after* release, so it counts
                // genuinely executing bodies, not parked ones.
                while st.live < hub.nranks && !st.poisoned {
                    st = hub.cv.wait(st).unwrap();
                }
                st.running += 1;
                st.stats.max_concurrent_ranks = st.stats.max_concurrent_ranks.max(st.running);
            }
            TransportKind::Lockstep => {
                while st.turn != self.rank && !st.poisoned {
                    st = hub.cv.wait(st).unwrap();
                }
                st.running += 1;
                st.stats.max_concurrent_ranks = st.stats.max_concurrent_ranks.max(st.running);
            }
        }
        assert!(!st.poisoned, "rank {}: a peer rank failed", self.rank);
    }

    /// Mark this rank's body complete and hand over scheduling.
    fn finish(&self) {
        let hub = &*self.hub;
        let mut st = hub.state.lock().unwrap();
        st.stats.overlapped_rows += self.overlap_rows;
        st.finished[self.rank] = true;
        st.running = st.running.saturating_sub(1);
        st.idle = 0;
        if hub.kind == TransportKind::Lockstep && st.turn == self.rank {
            advance_turn(&mut st, hub.nranks);
        }
        hub.cv.notify_all();
    }

    /// Block until `op` succeeds against the hub state. Lockstep yields
    /// the turn on every failed attempt and re-runs only when the baton
    /// comes back; threaded parks on the condvar. Panics on poisoning,
    /// detected lockstep deadlock cycles, or threaded timeout.
    fn wait_for<T>(&self, what: &str, mut op: impl FnMut(&mut HubState) -> Option<T>) -> T {
        let hub = &*self.hub;
        // one absolute deadline per blocking episode (threaded): wakeups
        // from unrelated traffic must not keep resetting the window, or
        // a genuinely stuck rank would only be diagnosed once the whole
        // run quiesces
        let deadline = std::time::Instant::now() + hub.deadlock_timeout;
        let mut st = hub.state.lock().unwrap();
        loop {
            if st.poisoned {
                panic!("rank {}: aborting {what}: a peer rank failed", self.rank);
            }
            match hub.kind {
                TransportKind::Lockstep => {
                    debug_assert_eq!(st.turn, self.rank, "lockstep op outside of turn");
                    if let Some(v) = op(&mut st) {
                        st.idle = 0;
                        return v;
                    }
                    st.idle += 1;
                    if st.idle > 2 * hub.nranks + 2 {
                        // a full cycle of yields with zero communication
                        // progress: every rank is blocked — deadlock
                        st.poisoned = true;
                        hub.cv.notify_all();
                        panic!("rank {}: lockstep deadlock waiting for {what}", self.rank);
                    }
                    st.running -= 1;
                    advance_turn(&mut st, hub.nranks);
                    hub.cv.notify_all();
                    while st.turn != self.rank && !st.poisoned {
                        st = hub.cv.wait(st).unwrap();
                    }
                    st.running += 1;
                    st.stats.max_concurrent_ranks = st.stats.max_concurrent_ranks.max(st.running);
                }
                TransportKind::Threaded => {
                    if let Some(v) = op(&mut st) {
                        return v;
                    }
                    st.running -= 1;
                    let remaining =
                        deadline.saturating_duration_since(std::time::Instant::now());
                    let (guard, timeout) = hub.cv.wait_timeout(st, remaining).unwrap();
                    st = guard;
                    st.running += 1;
                    st.stats.max_concurrent_ranks = st.stats.max_concurrent_ranks.max(st.running);
                    if (timeout.timed_out() || remaining.is_zero()) && !st.poisoned {
                        if let Some(v) = op(&mut st) {
                            return v;
                        }
                        st.poisoned = true;
                        hub.cv.notify_all();
                        panic!(
                            "rank {}: transport deadlock (timeout) waiting for {what}",
                            self.rank
                        );
                    }
                }
            }
        }
    }
}

impl Transport for RankTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nranks(&self) -> usize {
        self.hub.nranks
    }

    fn send(&mut self, dst: usize, tag: Tag, comm: Comm, data: &[f64]) {
        let hub = &*self.hub;
        assert!(dst < hub.nranks, "bad rank");
        let mut st = hub.state.lock().unwrap();
        debug_assert!(
            hub.kind == TransportKind::Threaded || st.turn == self.rank,
            "lockstep op outside of turn"
        );
        st.stats.p2p_messages += 1;
        st.stats.p2p_bytes += (data.len() * 8) as u64;
        // copy into a recycled buffer: after warmup the pool holds a
        // buffer of matching capacity for every in-flight plane
        let mut buf = st.spare_bufs.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(data);
        st.mailboxes
            .entry((self.rank, dst, tag, comm))
            .or_default()
            .push_back(buf);
        st.idle = 0;
        hub.cv.notify_all();
    }

    fn recv(&mut self, src: usize, tag: Tag, comm: Comm) -> Vec<f64> {
        let key = (src, self.rank, tag, comm);
        self.wait_for("recv", move |st| {
            st.mailboxes.get_mut(&key).and_then(|q| q.pop_front())
        })
    }

    fn recv_into(&mut self, src: usize, tag: Tag, comm: Comm, out: &mut [f64]) {
        let key = (src, self.rank, tag, comm);
        // a wrong-length message is reported *outside* the state lock:
        // panicking with the guard held would poison the mutex and kill
        // the peers with opaque PoisonErrors instead of the designed
        // "a peer rank failed" path (run_ranks poisons the hub for us)
        let mut bad_len = None;
        self.wait_for("recv", |st| {
            let q = st.mailboxes.get_mut(&key)?;
            let front_len = q.front()?.len();
            if front_len != out.len() {
                bad_len = Some(front_len);
                return Some(());
            }
            let buf = q.pop_front().expect("peeked message present");
            out.copy_from_slice(&buf);
            st.spare_bufs.push(buf);
            Some(())
        });
        if let Some(got) = bad_len {
            panic!(
                "rank {}: recv_into length mismatch on (src {src}, tag {tag}): \
                 got {got}, want {}",
                self.rank,
                out.len()
            );
        }
    }

    fn allreduce_start(&mut self, comm: Comm, tag: Tag, partial: Payload) {
        let round = {
            let c = self.ar_next.entry((comm, tag)).or_insert(0);
            let r = *c;
            *c += 1;
            r
        };
        self.ar_pending
            .entry((comm, tag))
            .or_default()
            .push_back(round);
        let key: ReduceKey = (comm, tag, round);
        let hub = &*self.hub;
        let n = hub.nranks;
        let mut st = hub.state.lock().unwrap();
        debug_assert!(
            hub.kind == TransportKind::Threaded || st.turn == self.rank,
            "lockstep op outside of turn"
        );
        let idx = match st.reductions.iter().position(|(k, _)| *k == key) {
            Some(i) => i,
            None => {
                let mut r = st.spare_rounds.pop().unwrap_or_default();
                r.reset(n);
                st.reductions.push((key, r));
                st.reductions.len() - 1
            }
        };
        let slot = &mut st.reductions[idx].1;
        debug_assert!(
            slot.parts[self.rank].is_none(),
            "double allreduce contribution"
        );
        slot.parts[self.rank] = Some(partial);
        slot.nparts += 1;
        let completed = slot.nparts == n;
        if completed {
            // every contribution is in: fold in the fixed rank order —
            // rank_fold is the one authority for the schedule, fed
            // straight from the slots (no per-round vector of parts)
            slot.result = Some(rank_fold_iter(
                slot.parts
                    .iter()
                    .map(|p| p.expect("counted contribution present")),
            ));
            st.stats.allreduces += 1;
        }
        st.idle = 0;
        hub.cv.notify_all();
    }

    fn record_overlap(&mut self, rows: u64) {
        // rank-local accumulation — flushed at `finish` so the hot path
        // adds no hub-lock traffic
        self.overlap_rows += rows;
    }

    fn allreduce_wait(&mut self, comm: Comm, tag: Tag) -> Payload {
        let round = self
            .ar_pending
            .get_mut(&(comm, tag))
            .and_then(|q| q.pop_front())
            .expect("allreduce_wait without a matching allreduce_start");
        let key: ReduceKey = (comm, tag, round);
        let me = self.rank;
        let n = self.hub.nranks;
        self.wait_for("allreduce", move |st| {
            let idx = st.reductions.iter().position(|(k, _)| *k == key)?;
            let slot = &mut st.reductions[idx].1;
            let result = slot.result?;
            debug_assert!(!slot.taken[me], "double allreduce_wait");
            slot.taken[me] = true;
            slot.ntaken += 1;
            if slot.ntaken == n {
                let (_, round) = st.reductions.swap_remove(idx);
                st.spare_rounds.push(round);
            }
            Some(result)
        })
    }
}

/// Execute one body per rank over a fresh hub and collect each body's
/// result plus the run's communication statistics. This is the single
/// entry point both `Problem::solve*` paths and the simmpi tests use:
/// every rank body runs on its own OS thread; the `kind` decides whether
/// those threads are serialised (lockstep oracle) or genuinely
/// concurrent (threaded hybrid execution).
///
/// A panic in any rank body poisons the hub (so no peer hangs waiting
/// for messages that will never come) and is re-raised once every
/// thread joined.
pub fn run_ranks<'env, R: Send + 'env>(
    kind: TransportKind,
    bodies: Vec<Box<dyn FnOnce(&mut RankTransport) -> R + Send + 'env>>,
) -> (Vec<R>, WorldStats) {
    let nranks = bodies.len();
    let hub = Arc::new(Hub::new(nranks, kind));
    let mut results: Vec<Option<R>> = Vec::with_capacity(nranks);
    results.resize_with(nranks, || None);
    std::thread::scope(|s| {
        for (rank, (body, slot)) in bodies.into_iter().zip(results.iter_mut()).enumerate() {
            let hub = Arc::clone(&hub);
            s.spawn(move || {
                let mut tp = RankTransport::new(hub, rank);
                tp.attach();
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    body(&mut tp)
                }));
                match out {
                    Ok(v) => {
                        *slot = Some(v);
                        tp.finish();
                    }
                    Err(payload) => {
                        tp.hub.poison();
                        std::panic::resume_unwind(payload);
                    }
                }
            });
        }
    });
    // the old `World::in_flight() == 0` end-of-run invariant: a clean
    // run leaves no undelivered messages and no unconsumed allreduce
    // rounds behind (panicked runs never reach this point — the scope
    // re-raises first)
    {
        let st = hub.state.lock().unwrap();
        debug_assert!(
            st.poisoned || st.mailboxes.values().all(|q| q.is_empty()),
            "undelivered messages left in flight"
        );
        debug_assert!(
            st.poisoned || st.reductions.is_empty(),
            "unconsumed allreduce rounds left behind"
        );
    }
    let stats = hub.stats();
    let results = results
        .into_iter()
        .map(|r| r.expect("rank body produced no result"))
        .collect();
    (results, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_turn_skips_finished() {
        let hub = Hub::new(3, TransportKind::Lockstep);
        let mut st = hub.state.lock().unwrap();
        st.finished[1] = true;
        advance_turn(&mut st, 3);
        assert_eq!(st.turn, 2);
        advance_turn(&mut st, 3);
        assert_eq!(st.turn, 0);
        st.finished[0] = true;
        st.finished[2] = true;
        advance_turn(&mut st, 3); // all finished: no move
        assert_eq!(st.turn, 0);
    }

    #[test]
    fn single_rank_roundtrip_both_kinds() {
        for kind in [TransportKind::Lockstep, TransportKind::Threaded] {
            let bodies: Vec<Box<dyn FnOnce(&mut RankTransport) -> f64 + Send>> =
                vec![Box::new(|tp: &mut RankTransport| {
                    // self-send is legal (a rank may message itself)
                    tp.send(0, 1, 0, &[2.5]);
                    let v = tp.recv(0, 1, 0);
                    let s = tp.allreduce(0, 0, Payload::scalar(v[0]));
                    s[0]
                })];
            let (got, stats) = run_ranks(kind, bodies);
            assert_eq!(got, vec![2.5], "{kind:?}");
            assert_eq!(stats.rank_threads, 1);
            assert_eq!(stats.max_concurrent_ranks, 1);
            assert_eq!(stats.allreduces, 1);
        }
    }

    #[test]
    #[should_panic(expected = "empty world")]
    fn empty_world_rejected() {
        let _ = Hub::new(0, TransportKind::Lockstep);
    }
}
