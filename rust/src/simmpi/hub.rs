//! The transport hub: one shared mailbox/reduction state per run, plus
//! the per-rank [`RankTransport`] handles the solver loops talk to.
//!
//! Both transport disciplines live here, sharing every data structure
//! and differing only in their wait/scheduling policy:
//!
//!  * **Lockstep** — the bit-exact oracle. A turn baton serialises rank
//!    bodies: a rank executes (compute *and* communication) only while it
//!    holds the turn, and yields it round-robin at every blocking call
//!    that cannot complete. Parked OS threads are merely the suspension
//!    mechanism for the inverted per-rank loops; at most one rank makes
//!    progress at any instant, which `WorldStats::max_concurrent_ranks
//!    == 1` asserts. A full turn cycle in which every rank declines to
//!    run is a communication deadlock and panics (the moral equivalent
//!    of the old `World::recv -> None`).
//!  * **Threaded** — real hybrid execution: every rank thread runs
//!    freely, blocking waits park on the condvar, and a startup barrier
//!    guarantees all rank threads exist concurrently before any body
//!    runs (the deterministic basis of the `rank_threads` accounting;
//!    `max_concurrent_ranks` then honestly samples how many bodies were
//!    observed executing at once). A wait that exceeds the deadlock
//!    timeout panics instead of hanging the test suite.
//!
//! Numbers never depend on the discipline: payloads are FIFO per
//! (src, dst, tag, comm) key, and allreduce partials fold via
//! [`super::rank_fold`] after all of them exist (see the determinism
//! contract in the module docs).

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::ThreadId;
use std::time::Duration;

use super::fault::{Fault, FaultKind, FaultPlan};
use super::{
    rank_fold_iter, Comm, MsgKey, Payload, Tag, Transport, TransportFailure, TransportKind,
    WorldStats,
};

/// Relative lane skew applied by [`FaultKind::SilentAllreduce`]: large
/// enough that the checksum scrub detects it robustly above fold
/// rounding (which is ~1e-14 × scale), small enough that every value
/// stays finite and plausible — the definition of a silent error.
const SILENT_SKEW: f64 = 1e-3;

/// One in-flight allreduce round on a (comm, tag) key. Rounds exist
/// because the ISODD split reuses keys every second iteration while a
/// fast rank may already be two allreduces ahead of a slow one.
/// Contributions and results are inline [`Payload`]s and finished rounds
/// return to `HubState::spare_rounds`, so the steady state recycles one
/// small struct per collective instead of allocating fresh vectors.
#[derive(Default)]
struct Round {
    parts: Vec<Option<Payload>>,
    nparts: usize,
    result: Option<Payload>,
    taken: Vec<bool>,
    ntaken: usize,
}

impl Round {
    /// Prepare a (possibly recycled) round for `nranks` contributions.
    fn reset(&mut self, nranks: usize) {
        self.parts.clear();
        self.parts.resize(nranks, None);
        self.nparts = 0;
        self.result = None;
        self.taken.clear();
        self.taken.resize(nranks, false);
        self.ntaken = 0;
    }
}

/// Key of one in-flight reduction: (comm, tag, round index).
type ReduceKey = (Comm, Tag, u64);

struct HubState {
    mailboxes: BTreeMap<MsgKey, VecDeque<Vec<f64>>>,
    /// In-flight reductions. A linear scan: at most a couple of rounds
    /// are ever open at once (the ISODD window), and the Vec keeps its
    /// capacity across rounds where a tree would churn nodes.
    reductions: Vec<(ReduceKey, Round)>,
    /// Recycled message payload buffers (capacity-preserving): `send`
    /// pops one, `recv_into` pushes the consumed buffer back.
    spare_bufs: Vec<Vec<f64>>,
    /// Recycled reduction rounds.
    spare_rounds: Vec<Round>,
    stats: WorldStats,
    thread_ids: HashSet<ThreadId>,
    /// Lockstep: the rank currently allowed to execute.
    turn: usize,
    finished: Vec<bool>,
    /// Ranks that have attached (threaded startup barrier).
    live: usize,
    /// Rank bodies currently executing (not parked in a wait).
    running: usize,
    /// Consecutive turn yields without any communication progress
    /// (lockstep deadlock detector).
    idle: usize,
    /// A rank panicked (or a deadlock was detected): everyone aborts.
    poisoned: bool,
}

/// Shared transport state for one `run_ranks` invocation.
pub struct Hub {
    state: Mutex<HubState>,
    cv: Condvar,
    kind: TransportKind,
    nranks: usize,
    /// Threaded blocking-wait bound; a genuine solve never comes close,
    /// so exceeding it is reported as a deadlock.
    deadlock_timeout: Duration,
}

/// Threaded blocking-wait bound when no per-run override is given: the
/// `HLAM_DEADLOCK_TIMEOUT_MS` environment knob if set (tests drop it to
/// ~2s so fault suites fail fast), else 30s — far beyond any genuine
/// solve, so exceeding it is a deadlock.
fn default_deadlock_timeout() -> Duration {
    std::env::var("HLAM_DEADLOCK_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_secs(30))
}

impl Hub {
    pub fn new(nranks: usize, kind: TransportKind) -> Self {
        Hub::with_timeout(nranks, kind, None)
    }

    /// A hub with an explicit deadlock-timeout override (`None` falls
    /// back to `HLAM_DEADLOCK_TIMEOUT_MS`, then the 30s default).
    pub fn with_timeout(
        nranks: usize,
        kind: TransportKind,
        deadlock_timeout: Option<Duration>,
    ) -> Self {
        assert!(nranks > 0, "empty world");
        Hub {
            state: Mutex::new(HubState {
                mailboxes: BTreeMap::new(),
                reductions: Vec::new(),
                spare_bufs: Vec::new(),
                spare_rounds: Vec::new(),
                stats: WorldStats::default(),
                thread_ids: HashSet::new(),
                turn: 0,
                finished: vec![false; nranks],
                live: 0,
                running: 0,
                idle: 0,
                poisoned: false,
            }),
            cv: Condvar::new(),
            kind,
            nranks,
            deadlock_timeout: deadlock_timeout.unwrap_or_else(default_deadlock_timeout),
        }
    }

    /// Lock the hub state, surviving mutex poisoning: a rank that
    /// panicked while holding the guard must not convert every peer's
    /// designed "a peer rank failed" abort into an opaque PoisonError.
    fn lock(&self) -> MutexGuard<'_, HubState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn kind(&self) -> TransportKind {
        self.kind
    }

    pub fn nranks(&self) -> usize {
        self.nranks
    }

    /// Communication statistics so far (final after the scope joined).
    pub fn stats(&self) -> WorldStats {
        let st = self.lock();
        let mut s = st.stats.clone();
        s.rank_threads = st.thread_ids.len();
        s
    }

    /// Abort the run: wake every parked rank into a panic.
    fn poison(&self) {
        let mut st = self.lock();
        st.poisoned = true;
        self.cv.notify_all();
    }
}

/// Pass the lockstep turn to the next unfinished rank (round-robin).
fn advance_turn(st: &mut HubState, nranks: usize) {
    for step in 1..=nranks {
        let cand = (st.turn + step) % nranks;
        if !st.finished[cand] {
            st.turn = cand;
            return;
        }
    }
    // everyone finished: leave the turn where it is
}

/// Per-rank communication handle (the `Transport` implementation).
pub struct RankTransport {
    hub: Arc<Hub>,
    rank: usize,
    /// Next round index per (comm, tag) this rank will contribute to.
    ar_next: BTreeMap<(Comm, Tag), u64>,
    /// Rounds contributed but not yet waited on, oldest first.
    ar_pending: BTreeMap<(Comm, Tag), VecDeque<u64>>,
    /// Overlap-effectiveness rows accumulated rank-locally
    /// (`Transport::record_overlap`) and flushed into the hub stats once
    /// at [`RankTransport::finish`] — the hot path never takes the hub
    /// lock just to bump this counter.
    overlap_rows: u64,
    /// This rank's injected faults (empty on real runs — the fault-free
    /// hot path is a single `is_empty` branch and counts nothing).
    faults: Vec<Fault>,
    /// Ordinal of the next blocking wait (fault trigger counter).
    wait_count: usize,
    /// Ordinal of the next allreduce contribution (fault trigger
    /// counter).
    ar_count: usize,
}

impl RankTransport {
    fn new(hub: Arc<Hub>, rank: usize) -> Self {
        assert!(rank < hub.nranks, "bad rank");
        RankTransport {
            hub,
            rank,
            ar_next: BTreeMap::new(),
            ar_pending: BTreeMap::new(),
            overlap_rows: 0,
            faults: Vec::new(),
            wait_count: 0,
            ar_count: 0,
        }
    }

    /// Fault hook at the entry of every blocking wait: stalls sleep
    /// (numerics untouched), aborts unwind with a structured
    /// [`TransportFailure`], panics unwind raw (exercising the service
    /// layer's catch_unwind containment). Trigger points are counted
    /// per rank, so replays are deterministic.
    fn inject_wait_faults(&mut self, phase: &str) {
        if self.faults.is_empty() {
            return;
        }
        let ord = self.wait_count;
        self.wait_count += 1;
        for f in &self.faults {
            match f.kind {
                FaultKind::Stall if ord < f.at => {
                    std::thread::sleep(Duration::from_millis(f.delay_ms));
                }
                FaultKind::Abort if ord == f.at => {
                    self.hub.poison();
                    std::panic::panic_any(TransportFailure {
                        rank: self.rank,
                        phase: phase.to_string(),
                        what: "injected abort".to_string(),
                    });
                }
                FaultKind::Panic if ord == f.at => {
                    panic!("rank {}: injected panic at {phase} #{ord}", self.rank);
                }
                _ => {}
            }
        }
    }

    /// Fault hook on every allreduce contribution: delays sleep before
    /// posting (numerics untouched), corruptions mutate the data lanes
    /// *in place* — NaN for the loud kind, a finite skew for the silent
    /// one — leaving any sealed checksum lane intact, since the fault
    /// models damage in flight after the contributor checksummed it.
    /// The fixed fold propagates the damage to every rank identically,
    /// so solver guards fail in lockstep instead of deadlocking the
    /// transport.
    fn inject_allreduce_faults(&mut self, partial: Payload) -> Payload {
        if self.faults.is_empty() {
            return partial;
        }
        let ord = self.ar_count;
        self.ar_count += 1;
        let mut out = partial;
        for f in &self.faults {
            if ord != f.at {
                continue;
            }
            match f.kind {
                FaultKind::DelayAllreduce => {
                    std::thread::sleep(Duration::from_millis(f.delay_ms));
                }
                FaultKind::CorruptAllreduce => {
                    out.corrupt_lanes_nan();
                }
                FaultKind::SilentAllreduce => {
                    out.skew_lanes(SILENT_SKEW);
                }
                _ => {}
            }
        }
        out
    }

    /// Register this rank's thread and enter the scheduling discipline:
    /// lockstep ranks wait for the turn baton, threaded ranks pass a
    /// startup barrier that releases all of them at once (the observed
    /// cross-rank overlap the acceptance criteria ask for).
    fn attach(&self) {
        let hub = &*self.hub;
        let mut st = hub.lock();
        st.thread_ids.insert(std::thread::current().id());
        st.live += 1;
        hub.cv.notify_all();
        match hub.kind {
            TransportKind::Threaded => {
                // startup barrier: all rank threads must exist before any
                // body runs (the rendezvous behind `rank_threads`). The
                // running gauge starts only *after* release, so it counts
                // genuinely executing bodies, not parked ones.
                while st.live < hub.nranks && !st.poisoned {
                    st = hub.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                }
                st.running += 1;
                st.stats.max_concurrent_ranks = st.stats.max_concurrent_ranks.max(st.running);
            }
            TransportKind::Lockstep => {
                while st.turn != self.rank && !st.poisoned {
                    st = hub.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                }
                st.running += 1;
                st.stats.max_concurrent_ranks = st.stats.max_concurrent_ranks.max(st.running);
            }
        }
        if st.poisoned {
            // drop the guard first: the structured peer-echo abort must
            // not poison the mutex on its way out
            drop(st);
            std::panic::panic_any(TransportFailure {
                rank: self.rank,
                phase: "attach".to_string(),
                what: "a peer rank failed".to_string(),
            });
        }
    }

    /// Mark this rank's body complete and hand over scheduling.
    fn finish(&self) {
        let hub = &*self.hub;
        let mut st = hub.lock();
        st.stats.overlapped_rows += self.overlap_rows;
        st.finished[self.rank] = true;
        st.running = st.running.saturating_sub(1);
        st.idle = 0;
        if hub.kind == TransportKind::Lockstep && st.turn == self.rank {
            advance_turn(&mut st, hub.nranks);
        }
        hub.cv.notify_all();
    }

    /// Block until `op` succeeds against the hub state. Lockstep yields
    /// the turn on every failed attempt and re-runs only when the baton
    /// comes back; threaded parks on the condvar. Poisoning, detected
    /// lockstep deadlock cycles, and threaded timeouts unwind with a
    /// structured [`TransportFailure`] (the guard is dropped first so
    /// the mutex is never poisoned by the designed failure path), which
    /// [`try_run_ranks`] converts into a returned error.
    fn wait_for<T>(&mut self, what: &str, mut op: impl FnMut(&mut HubState) -> Option<T>) -> T {
        self.inject_wait_faults(what);
        let hub = &*self.hub;
        let rank = self.rank;
        let fail = |st: MutexGuard<'_, HubState>, cause: String| -> ! {
            drop(st);
            std::panic::panic_any(TransportFailure {
                rank,
                phase: what.to_string(),
                what: cause,
            })
        };
        // one absolute deadline per blocking episode (threaded): wakeups
        // from unrelated traffic must not keep resetting the window, or
        // a genuinely stuck rank would only be diagnosed once the whole
        // run quiesces
        let deadline = std::time::Instant::now() + hub.deadlock_timeout;
        let mut st = hub.lock();
        loop {
            if st.poisoned {
                fail(st, "a peer rank failed".to_string());
            }
            match hub.kind {
                TransportKind::Lockstep => {
                    debug_assert_eq!(st.turn, rank, "lockstep op outside of turn");
                    if let Some(v) = op(&mut st) {
                        st.idle = 0;
                        return v;
                    }
                    st.idle += 1;
                    if st.idle > 2 * hub.nranks + 2 {
                        // a full cycle of yields with zero communication
                        // progress: every rank is blocked — deadlock
                        st.poisoned = true;
                        hub.cv.notify_all();
                        fail(st, "lockstep deadlock: every rank is blocked".to_string());
                    }
                    st.running -= 1;
                    advance_turn(&mut st, hub.nranks);
                    hub.cv.notify_all();
                    while st.turn != rank && !st.poisoned {
                        st = hub.cv.wait(st).unwrap_or_else(|e| e.into_inner());
                    }
                    st.running += 1;
                    st.stats.max_concurrent_ranks = st.stats.max_concurrent_ranks.max(st.running);
                }
                TransportKind::Threaded => {
                    if let Some(v) = op(&mut st) {
                        return v;
                    }
                    st.running -= 1;
                    let remaining =
                        deadline.saturating_duration_since(std::time::Instant::now());
                    let (guard, timeout) = hub
                        .cv
                        .wait_timeout(st, remaining)
                        .unwrap_or_else(|e| e.into_inner());
                    st = guard;
                    st.running += 1;
                    st.stats.max_concurrent_ranks = st.stats.max_concurrent_ranks.max(st.running);
                    if (timeout.timed_out() || remaining.is_zero()) && !st.poisoned {
                        if let Some(v) = op(&mut st) {
                            return v;
                        }
                        st.poisoned = true;
                        hub.cv.notify_all();
                        fail(
                            st,
                            format!(
                                "deadlock: wait exceeded the {:?} timeout",
                                hub.deadlock_timeout
                            ),
                        );
                    }
                }
            }
        }
    }
}

impl Transport for RankTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn nranks(&self) -> usize {
        self.hub.nranks
    }

    fn send(&mut self, dst: usize, tag: Tag, comm: Comm, data: &[f64]) {
        let hub = &*self.hub;
        assert!(dst < hub.nranks, "bad rank");
        let mut st = hub.lock();
        debug_assert!(
            hub.kind == TransportKind::Threaded || st.turn == self.rank,
            "lockstep op outside of turn"
        );
        st.stats.p2p_messages += 1;
        st.stats.p2p_bytes += (data.len() * 8) as u64;
        // copy into a recycled buffer: after warmup the pool holds a
        // buffer of matching capacity for every in-flight plane
        let mut buf = st.spare_bufs.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(data);
        st.mailboxes
            .entry((self.rank, dst, tag, comm))
            .or_default()
            .push_back(buf);
        st.idle = 0;
        hub.cv.notify_all();
    }

    fn recv(&mut self, src: usize, tag: Tag, comm: Comm) -> Vec<f64> {
        let key = (src, self.rank, tag, comm);
        self.wait_for("recv", move |st| {
            st.mailboxes.get_mut(&key).and_then(|q| q.pop_front())
        })
    }

    fn recv_into(&mut self, src: usize, tag: Tag, comm: Comm, out: &mut [f64]) {
        let key = (src, self.rank, tag, comm);
        // a wrong-length message is reported *outside* the state lock:
        // panicking with the guard held would poison the mutex and kill
        // the peers with opaque PoisonErrors instead of the designed
        // "a peer rank failed" path (run_ranks poisons the hub for us)
        let mut bad_len = None;
        self.wait_for("recv", |st| {
            let q = st.mailboxes.get_mut(&key)?;
            let front_len = q.front()?.len();
            if front_len != out.len() {
                bad_len = Some(front_len);
                return Some(());
            }
            let buf = q.pop_front().expect("peeked message present");
            out.copy_from_slice(&buf);
            st.spare_bufs.push(buf);
            Some(())
        });
        if let Some(got) = bad_len {
            panic!(
                "rank {}: recv_into length mismatch on (src {src}, tag {tag}): \
                 got {got}, want {}",
                self.rank,
                out.len()
            );
        }
    }

    fn allreduce_start(&mut self, comm: Comm, tag: Tag, partial: Payload) {
        let partial = self.inject_allreduce_faults(partial);
        let round = {
            let c = self.ar_next.entry((comm, tag)).or_insert(0);
            let r = *c;
            *c += 1;
            r
        };
        self.ar_pending
            .entry((comm, tag))
            .or_default()
            .push_back(round);
        let key: ReduceKey = (comm, tag, round);
        let hub = &*self.hub;
        let n = hub.nranks;
        let mut st = hub.lock();
        debug_assert!(
            hub.kind == TransportKind::Threaded || st.turn == self.rank,
            "lockstep op outside of turn"
        );
        let idx = match st.reductions.iter().position(|(k, _)| *k == key) {
            Some(i) => i,
            None => {
                let mut r = st.spare_rounds.pop().unwrap_or_default();
                r.reset(n);
                st.reductions.push((key, r));
                st.reductions.len() - 1
            }
        };
        let slot = &mut st.reductions[idx].1;
        debug_assert!(
            slot.parts[self.rank].is_none(),
            "double allreduce contribution"
        );
        slot.parts[self.rank] = Some(partial);
        slot.nparts += 1;
        let completed = slot.nparts == n;
        if completed {
            // every contribution is in: fold in the fixed rank order —
            // rank_fold is the one authority for the schedule, fed
            // straight from the slots (no per-round vector of parts)
            slot.result = Some(rank_fold_iter(
                slot.parts
                    .iter()
                    .map(|p| p.expect("counted contribution present")),
            ));
            st.stats.allreduces += 1;
        }
        st.idle = 0;
        hub.cv.notify_all();
    }

    fn record_overlap(&mut self, rows: u64) {
        // rank-local accumulation — flushed at `finish` so the hot path
        // adds no hub-lock traffic
        self.overlap_rows += rows;
    }

    fn allreduce_wait(&mut self, comm: Comm, tag: Tag) -> Payload {
        let round = self
            .ar_pending
            .get_mut(&(comm, tag))
            .and_then(|q| q.pop_front())
            .expect("allreduce_wait without a matching allreduce_start");
        let key: ReduceKey = (comm, tag, round);
        let me = self.rank;
        let n = self.hub.nranks;
        self.wait_for("allreduce", move |st| {
            let idx = st.reductions.iter().position(|(k, _)| *k == key)?;
            let slot = &mut st.reductions[idx].1;
            let result = slot.result?;
            debug_assert!(!slot.taken[me], "double allreduce_wait");
            slot.taken[me] = true;
            slot.ntaken += 1;
            if slot.ntaken == n {
                let (_, round) = st.reductions.swap_remove(idx);
                st.spare_rounds.push(round);
            }
            Some(result)
        })
    }
}

/// Execute one body per rank over a fresh hub and collect each body's
/// result plus the run's communication statistics. This is the single
/// entry point both `Problem::solve*` paths and the simmpi tests use:
/// every rank body runs on its own OS thread; the `kind` decides whether
/// those threads are serialised (lockstep oracle) or genuinely
/// concurrent (threaded hybrid execution).
///
/// A panic in any rank body poisons the hub (so no peer hangs waiting
/// for messages that will never come) and is re-raised once every
/// thread joined; transport failures (deadlock, timeout) panic with the
/// failure's message. [`try_run_ranks`] is the non-panicking form.
pub fn run_ranks<'env, R: Send + 'env>(
    kind: TransportKind,
    bodies: Vec<Box<dyn FnOnce(&mut RankTransport) -> R + Send + 'env>>,
) -> (Vec<R>, WorldStats) {
    match try_run_ranks(kind, bodies, &FaultPlan::none(), None) {
        Ok(out) => out,
        Err(tf) => panic!("{tf}"),
    }
}

/// [`run_ranks`] with structured failure reporting and deterministic
/// fault injection. Transport-layer failures — deadlocks, timeouts,
/// injected aborts, and the peer-echo aborts they cause — come back as
/// `Err(TransportFailure)` instead of a panic; the reported failure is
/// the *originating* one (lowest rank among non-peer-echo failures) so
/// the same chaos plan reports the same cause on every replay. Plain
/// panics in rank bodies (including injected `FaultKind::Panic`) are
/// NOT part of the transport taxonomy: they are re-raised after every
/// thread joined, for the caller's own catch_unwind seam (the service
/// layer's containment boundary).
pub fn try_run_ranks<'env, R: Send + 'env>(
    kind: TransportKind,
    bodies: Vec<Box<dyn FnOnce(&mut RankTransport) -> R + Send + 'env>>,
    faults: &FaultPlan,
    deadlock_timeout: Option<Duration>,
) -> Result<(Vec<R>, WorldStats), TransportFailure> {
    let nranks = bodies.len();
    let hub = Arc::new(Hub::with_timeout(nranks, kind, deadlock_timeout));
    let injected = faults.resolved(nranks);
    let mut results: Vec<Option<R>> = Vec::with_capacity(nranks);
    results.resize_with(nranks, || None);
    let mut failures: Vec<Option<TransportFailure>> = vec![None; nranks];
    let mut panics: Vec<Option<Box<dyn std::any::Any + Send>>> = Vec::with_capacity(nranks);
    panics.resize_with(nranks, || None);
    std::thread::scope(|s| {
        let slots = results
            .iter_mut()
            .zip(failures.iter_mut().zip(panics.iter_mut()));
        for (rank, (body, (slot, (fail_slot, panic_slot)))) in
            bodies.into_iter().zip(slots).enumerate()
        {
            let hub = Arc::clone(&hub);
            let mine: Vec<Fault> = injected.iter().filter(|f| f.rank == rank).copied().collect();
            s.spawn(move || {
                let mut tp = RankTransport::new(hub, rank);
                tp.faults = mine;
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    tp.attach();
                    let v = body(&mut tp);
                    tp.finish();
                    v
                }));
                match out {
                    Ok(v) => *slot = Some(v),
                    Err(payload) => {
                        tp.hub.poison();
                        match payload.downcast::<TransportFailure>() {
                            Ok(tf) => *fail_slot = Some(*tf),
                            Err(other) => *panic_slot = Some(other),
                        }
                    }
                }
            });
        }
    });
    // a plain (non-transport) panic is outside the taxonomy: re-raise
    // it for the caller's catch_unwind
    if let Some(payload) = panics.into_iter().flatten().next() {
        std::panic::resume_unwind(payload);
    }
    // the old `World::in_flight() == 0` end-of-run invariant: a clean
    // run leaves no undelivered messages and no unconsumed allreduce
    // rounds behind
    {
        let st = hub.lock();
        debug_assert!(
            st.poisoned || st.mailboxes.values().all(|q| q.is_empty()),
            "undelivered messages left in flight"
        );
        debug_assert!(
            st.poisoned || st.reductions.is_empty(),
            "unconsumed allreduce rounds left behind"
        );
    }
    // primary failure: prefer the originating fault over the peer-echo
    // aborts it caused, lowest rank first for a deterministic report
    let primary = failures
        .iter()
        .flatten()
        .find(|f| !f.is_peer_echo())
        .or_else(|| failures.iter().flatten().next())
        .cloned();
    if let Some(tf) = primary {
        return Err(tf);
    }
    let stats = hub.stats();
    let results = results
        .into_iter()
        .map(|r| r.expect("rank body produced no result"))
        .collect();
    Ok((results, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_turn_skips_finished() {
        let hub = Hub::new(3, TransportKind::Lockstep);
        let mut st = hub.state.lock().unwrap();
        st.finished[1] = true;
        advance_turn(&mut st, 3);
        assert_eq!(st.turn, 2);
        advance_turn(&mut st, 3);
        assert_eq!(st.turn, 0);
        st.finished[0] = true;
        st.finished[2] = true;
        advance_turn(&mut st, 3); // all finished: no move
        assert_eq!(st.turn, 0);
    }

    #[test]
    fn single_rank_roundtrip_both_kinds() {
        for kind in [TransportKind::Lockstep, TransportKind::Threaded] {
            let bodies: Vec<Box<dyn FnOnce(&mut RankTransport) -> f64 + Send>> =
                vec![Box::new(|tp: &mut RankTransport| {
                    // self-send is legal (a rank may message itself)
                    tp.send(0, 1, 0, &[2.5]);
                    let v = tp.recv(0, 1, 0);
                    let s = tp.allreduce(0, 0, Payload::scalar(v[0]));
                    s[0]
                })];
            let (got, stats) = run_ranks(kind, bodies);
            assert_eq!(got, vec![2.5], "{kind:?}");
            assert_eq!(stats.rank_threads, 1);
            assert_eq!(stats.max_concurrent_ranks, 1);
            assert_eq!(stats.allreduces, 1);
        }
    }

    #[test]
    #[should_panic(expected = "empty world")]
    fn empty_world_rejected() {
        let _ = Hub::new(0, TransportKind::Lockstep);
    }

    /// One closure per rank through the fallible entry point.
    fn try_per_rank<R: Send>(
        kind: TransportKind,
        nranks: usize,
        plan: &FaultPlan,
        timeout: Option<Duration>,
        body: impl Fn(&mut RankTransport) -> R + Sync,
    ) -> Result<(Vec<R>, WorldStats), TransportFailure> {
        let body = &body;
        let bodies: Vec<Box<dyn FnOnce(&mut RankTransport) -> R + Send + '_>> = (0..nranks)
            .map(|_| {
                Box::new(move |tp: &mut RankTransport| body(tp))
                    as Box<dyn FnOnce(&mut RankTransport) -> R + Send + '_>
            })
            .collect();
        try_run_ranks(kind, bodies, plan, timeout)
    }

    #[test]
    fn injected_abort_surfaces_as_structured_failure() {
        let plan = FaultPlan {
            seed: 0,
            faults: vec![Fault {
                kind: FaultKind::Abort,
                rank: 1,
                at: 0,
                delay_ms: 0,
            }],
        };
        for kind in [TransportKind::Lockstep, TransportKind::Threaded] {
            let err = try_per_rank(kind, 2, &plan, None, |tp| {
                tp.allreduce(0, 0, Payload::scalar(1.0))[0]
            })
            .err()
            .expect("injected abort must fail the run");
            assert_eq!(err.rank, 1, "{kind:?}");
            assert_eq!(err.what, "injected abort", "{kind:?}");
            assert!(!err.is_peer_echo());
        }
    }

    #[test]
    fn threaded_timeout_is_a_structured_failure() {
        let err = try_per_rank(
            TransportKind::Threaded,
            1,
            &FaultPlan::none(),
            Some(Duration::from_millis(50)),
            |tp| tp.recv(0, 99, 0), // a message nobody sends
        )
        .err()
        .expect("timeout must fail the run");
        assert_eq!(err.phase, "recv");
        assert!(err.what.contains("deadlock"), "{}", err.what);
    }

    #[test]
    fn lockstep_deadlock_is_a_structured_failure() {
        let err = try_per_rank(
            TransportKind::Lockstep,
            2,
            &FaultPlan::none(),
            None,
            |tp| tp.recv(1 - tp.rank(), 99, 0),
        )
        .err()
        .expect("cyclic wait must fail the run");
        assert!(err.what.contains("lockstep deadlock"), "{}", err.what);
    }

    #[test]
    fn corrupt_allreduce_propagates_nan_to_every_rank() {
        let plan = FaultPlan {
            seed: 0,
            faults: vec![Fault {
                kind: FaultKind::CorruptAllreduce,
                rank: 0,
                at: 0,
                delay_ms: 0,
            }],
        };
        for kind in [TransportKind::Lockstep, TransportKind::Threaded] {
            let (got, _) = try_per_rank(kind, 3, &plan, None, |tp| {
                tp.allreduce(0, 0, Payload::scalar(1.0))[0]
            })
            .expect("corruption is not a transport failure");
            assert!(got.iter().all(|v| v.is_nan()), "{kind:?}: {got:?}");
        }
    }

    #[test]
    fn corruption_kinds_break_sealed_checksum_only_when_injected() {
        // Every rank seals its contribution; the injected kinds mutate
        // lanes after sealing, so the folded payload's checksum drifts —
        // finite for the silent kind, infinite for the NaN kind — while
        // a clean round folds with only reassociation rounding.
        for (plan_kind, min_drift) in [
            (Some(FaultKind::SilentAllreduce), 1e-6),
            (Some(FaultKind::CorruptAllreduce), 1.0),
            (None, 0.0),
        ] {
            let plan = match plan_kind {
                Some(kind) => FaultPlan {
                    seed: 0,
                    faults: vec![Fault {
                        kind,
                        rank: 1,
                        at: 0,
                        delay_ms: 0,
                    }],
                },
                None => FaultPlan::none(),
            };
            for kind in [TransportKind::Lockstep, TransportKind::Threaded] {
                let (got, _) = try_per_rank(kind, 3, &plan, None, |tp| {
                    let mut p = Payload::pair(1.0 + tp.rank() as f64, 0.5);
                    p.seal();
                    tp.allreduce(0, 0, p).check_drift()
                })
                .expect("corruption is not a transport failure");
                for drift in got {
                    if plan_kind.is_some() {
                        assert!(drift > min_drift, "{kind:?}: drift {drift}");
                    } else {
                        assert!(drift < 1e-12, "{kind:?}: clean drift {drift}");
                    }
                }
            }
        }
    }

    #[test]
    fn stall_and_delay_leave_numerics_unchanged() {
        let plan = FaultPlan {
            seed: 0,
            faults: vec![
                Fault {
                    kind: FaultKind::Stall,
                    rank: 0,
                    at: 2,
                    delay_ms: 1,
                },
                Fault {
                    kind: FaultKind::DelayAllreduce,
                    rank: 1,
                    at: 0,
                    delay_ms: 1,
                },
            ],
        };
        for kind in [TransportKind::Lockstep, TransportKind::Threaded] {
            let run = |p: &FaultPlan| {
                try_per_rank(kind, 2, p, None, |tp| {
                    let a = tp.allreduce(0, 0, Payload::scalar(0.1 + tp.rank() as f64))[0];
                    tp.allreduce(0, 0, Payload::scalar(a * 0.5))[0]
                })
                .expect("delays must not fail the run")
                .0
            };
            let faulty = run(&plan);
            let clean = run(&FaultPlan::none());
            let fb: Vec<u64> = faulty.iter().map(|v| v.to_bits()).collect();
            let cb: Vec<u64> = clean.iter().map(|v| v.to_bits()).collect();
            assert_eq!(fb, cb, "{kind:?}");
        }
    }

    #[test]
    fn injected_panic_reraises_for_caller_catch_unwind() {
        let plan = FaultPlan {
            seed: 0,
            faults: vec![Fault {
                kind: FaultKind::Panic,
                rank: 0,
                at: 0,
                delay_ms: 0,
            }],
        };
        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            try_per_rank(TransportKind::Lockstep, 2, &plan, None, |tp| {
                tp.allreduce(0, 0, Payload::scalar(1.0))[0]
            })
        }));
        assert!(out.is_err(), "injected panic must re-raise, not Err");
    }

    #[test]
    fn deadlock_timeout_env_knob_parses() {
        // resolution order: explicit override > env > 30s default; the
        // env var itself is exercised by the chaos integration suite
        assert_eq!(
            Hub::with_timeout(1, TransportKind::Threaded, Some(Duration::from_millis(7)))
                .deadlock_timeout,
            Duration::from_millis(7)
        );
        let hub = Hub::new(1, TransportKind::Threaded);
        assert!(hub.deadlock_timeout >= Duration::from_millis(1));
    }
}
