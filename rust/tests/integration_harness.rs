//! Integration: the figure harness produces well-formed outputs whose
//! *shape* matches the paper's findings (who wins, roughly by how much,
//! where crossovers fall).

use hlam::harness::{self, weak_config, HarnessOpts};
use hlam::simulator::{repeat_runs, ExecModel};
use hlam::sparse::StencilKind;
use hlam::stats::median;

fn opts() -> HarnessOpts {
    HarnessOpts {
        reps: 5,
        quick: true,
        ..Default::default()
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("hlam_it_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn fig3_weak_scaling_shape() {
    let dir = tmp("fig3");
    let out = harness::fig3(&dir, &opts());
    assert!(out.contains("panel 3a"));
    let csv = std::fs::read_to_string(dir.join("fig3_weak_ksm.csv")).unwrap();
    // collect efficiencies: (panel, method, model, nodes) -> eff
    let mut eff = std::collections::BTreeMap::new();
    for line in csv.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        eff.insert(
            (
                f[0].to_string(),
                f[1].to_string(),
                f[2].to_string(),
                f[3].parse::<usize>().unwrap(),
            ),
            f[5].parse::<f64>().unwrap(),
        );
    }
    // paper: task-based CG-NB ~1.2x over MPI-only classic at 64 nodes
    let oss = eff[&("3a".into(), "cg-nb".into(), "MPI-OSS_t".into(), 64)];
    let mpi = eff[&("3a".into(), "cg".into(), "MPI-only".into(), 64)];
    assert!(
        oss / mpi > 1.08 && oss / mpi < 1.6,
        "3a 64-node OSS/MPI = {:.3} (paper 1.197)",
        oss / mpi
    );
    // MPI-only efficiency decays with node count
    let mpi1 = eff[&("3a".into(), "cg".into(), "MPI-only".into(), 1)];
    assert!(mpi < mpi1, "MPI-only should degrade: {mpi} vs {mpi1}");
    // 27-pt stencil: task advantage at least as large (paper: 25%)
    let oss27 = eff[&("3b".into(), "cg-nb".into(), "MPI-OSS_t".into(), 64)];
    let mpi27 = eff[&("3b".into(), "cg".into(), "MPI-only".into(), 64)];
    assert!(oss27 / mpi27 > 1.08, "3b ratio {:.3}", oss27 / mpi27);
}

#[test]
fn fig4_jacobi_gs_shape() {
    let dir = tmp("fig4");
    let _ = harness::fig4(&dir, &opts());
    let csv = std::fs::read_to_string(dir.join("fig4_weak_jacobi_gs.csv")).unwrap();
    let mut eff = std::collections::BTreeMap::new();
    for line in csv.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        eff.insert(
            (f[0].to_string(), f[1].to_string(), f[2].to_string(), f[3].parse::<usize>().unwrap()),
            f[5].parse::<f64>().unwrap(),
        );
    }
    // paper: Jacobi OSS_t 14.4% over MPI-only at 64 nodes (7-pt)
    let oss = eff[&("4a".into(), "jacobi".into(), "MPI-OSS_t".into(), 64)];
    let mpi = eff[&("4a".into(), "jacobi".into(), "MPI-only".into(), 64)];
    assert!(oss / mpi > 1.05, "4a ratio {:.3} (paper 1.144)", oss / mpi);
}

#[test]
fn fig5_strong_scaling_shape() {
    let dir = tmp("fig5");
    let _ = harness::fig56(5, &dir, &opts());
    let csv = std::fs::read_to_string(dir.join("fig5_strong.csv")).unwrap();
    let mut eff = std::collections::BTreeMap::new();
    for line in csv.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        eff.insert(
            (f[0].to_string(), f[1].to_string(), f[2].to_string(), f[3].parse::<usize>().unwrap()),
            f[5].parse::<f64>().unwrap(),
        );
    }
    // §4.4: "the task-based versions start with a competitive advantage
    // that cancels out progressively" — at 64 nodes strong scaling the
    // remaining gap is modest, and much smaller than the weak-scaling
    // advantage at the same node count (Fig 3a: ~1.20x).
    let ratio64 = eff[&("5a".into(), "cg-nb".into(), "MPI-OSS_t".into(), 64)]
        / eff[&("5a".into(), "cg".into(), "MPI-only".into(), 64)];
    assert!(
        ratio64 < 1.20,
        "strong-scaling task advantage at 64 nodes should be modest: {ratio64:.3}"
    );
    // Jacobi OSS_t stays efficient (superscalability regime)
    let oss16 = eff[&("5c".into(), "jacobi".into(), "MPI-OSS_t".into(), 16)];
    assert!(oss16 > 0.9, "5c OSS at 16 nodes = {oss16}");
}

#[test]
fn fig2_variability_ordering() {
    // Fig 2's headline: OmpSs-2 reduces execution-time variability.
    let o = opts();
    let mk = |model| weak_config(model, "cg", StencilKind::P7, 16, &o);
    let iqr = |v: &[f64]| {
        let mut s = v.to_vec();
        s.sort_by(f64::total_cmp);
        s[(3 * s.len()) / 4] - s[s.len() / 4]
    };
    let mpi = repeat_runs(&mk(ExecModel::MpiOnly), 10);
    let oss = repeat_runs(&mk(ExecModel::MpiOssTask), 10);
    assert!(iqr(&oss) < iqr(&mpi));
    // and the median ordering matches Fig 2(a): OSS_t fastest
    assert!(median(&oss) < median(&mpi));
}

#[test]
fn granularity_optimum_in_paper_range() {
    let dir = tmp("gran");
    let out = harness::granularity_sweep(&dir, &HarnessOpts::default());
    assert!(out.contains("optimum"));
    let csv = std::fs::read_to_string(dir.join("granularity.csv")).unwrap();
    // find the best ntasks for w=7: paper says ~800 with a fair interval;
    // accept anything in [96, 6000] but NOT the extremes of the sweep
    let mut best = (0usize, f64::MAX);
    for line in csv.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f[0] == "7" {
            let nt: usize = f[1].parse().unwrap();
            let t: f64 = f[2].parse().unwrap();
            if t < best.1 {
                best = (nt, t);
            }
        }
    }
    assert!(
        best.0 >= 96 && best.0 <= 6000,
        "optimum {} outside the paper's plausible interval",
        best.0
    );
}

#[test]
fn latency_table_two_orders() {
    let dir = tmp("lat");
    let out = harness::latency_table(&dir);
    assert!(out.contains("synthetic"));
    let csv = std::fs::read_to_string(dir.join("latency.csv")).unwrap();
    for line in csv.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        let ranks: usize = f[0].parse().unwrap();
        let synth: f64 = f[1].parse().unwrap();
        let inapp: f64 = f[2].parse().unwrap();
        if ranks >= 384 {
            assert!(
                inapp / synth > 10.0,
                "{ranks} ranks: in-app {inapp} vs synthetic {synth}"
            );
        }
    }
}

#[test]
fn headline_csv_written() {
    let dir = tmp("headline");
    let out = harness::headline(&dir, &opts());
    assert!(out.contains("cg-nb"));
    let csv = std::fs::read_to_string(dir.join("headline.csv")).unwrap();
    assert_eq!(csv.lines().count(), 9); // header + 8 rows
    // every measured speedup is positive (tasks win everywhere at 64 nodes)
    for line in csv.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        let measured: f64 = f[3].parse().unwrap();
        assert!(measured > 0.0, "{line}");
    }
}
