//! Integration: the XLA backend (AOT JAX/Pallas artifacts through PJRT)
//! must agree with the native Rust kernels across whole solver runs.
//!
//! Requires `make artifacts` (the `test` preset sizes: n=512 w=7/27 with
//! halo 0 and 64). Tests panic with guidance if artifacts are missing —
//! the Makefile's `test` target always builds them first.

use std::rc::Rc;

use hlam::mesh::Grid3;
use hlam::runtime::{Runtime, XlaCompute};
use hlam::solvers::{Method, Native, Problem, SolveOpts};
use hlam::sparse::StencilKind;

fn runtime() -> Option<Rc<Runtime>> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(Rc::new(rt)),
        Err(e) => {
            // Graceful skip for cargo-test-without-make, loud enough to see.
            eprintln!("SKIP integration_xla: {e:#}");
            None
        }
    }
}

fn xla_for(rt: &Rc<Runtime>, pb: &Problem) -> XlaCompute {
    let st = &pb.ranks[0];
    XlaCompute::new(
        rt.clone(),
        st.n(),
        pb.kind.width(),
        st.sys.part.n_ext(),
    )
    .expect("test-preset artifacts present")
}

/// Single-rank 8x8x8 grid = the n=512, halo=0 artifact layout.
fn grid1() -> Grid3 {
    Grid3::new(8, 8, 8)
}

/// Two-rank 8x8x16 grid = n=512 per rank, halo=64 (one plane).
fn grid2() -> Grid3 {
    Grid3::new(8, 8, 16)
}

#[test]
fn xla_matches_native_cg() {
    let Some(rt) = runtime() else { return };
    for kind in [StencilKind::P7, StencilKind::P27] {
        let opts = SolveOpts::default();
        let mut pn = Problem::build(grid1(), kind, 1);
        let sn = pn.solve(Method::parse("cg").unwrap(), &opts, &mut Native);
        let mut px = Problem::build(grid1(), kind, 1);
        let mut xc = xla_for(&rt, &px);
        let sx = px.solve(Method::parse("cg").unwrap(), &opts, &mut xc);
        assert_eq!(sn.iterations, sx.iterations, "{kind:?}");
        assert!(sx.converged);
        assert!(
            (sn.rel_residual - sx.rel_residual).abs() < 1e-9,
            "{kind:?}: native {} vs xla {}",
            sn.rel_residual,
            sx.rel_residual
        );
        assert!(sx.x_error < 1e-5);
    }
}

#[test]
fn xla_matches_native_all_methods_single_rank() {
    let Some(rt) = runtime() else { return };
    for method in ["cg-nb", "bicgstab", "bicgstab-b1", "jacobi", "gs-rb"] {
        let opts = SolveOpts::default();
        let mut pn = Problem::build(grid1(), StencilKind::P7, 1);
        let sn = pn.solve(Method::parse(method).unwrap(), &opts, &mut Native);
        let mut px = Problem::build(grid1(), StencilKind::P7, 1);
        let mut xc = xla_for(&rt, &px);
        let sx = px.solve(Method::parse(method).unwrap(), &opts, &mut xc);
        assert!(sx.converged, "{method} xla did not converge");
        // GS colour sweeps have different intra-sweep semantics between
        // live-native and snapshot-XLA (documented); iteration counts may
        // differ there, everything else must match exactly.
        if method != "gs-rb" {
            assert_eq!(sn.iterations, sx.iterations, "{method}");
        }
        assert!(sx.x_error < 1e-4, "{method}: x_err {}", sx.x_error);
    }
}

#[test]
fn xla_two_rank_halo_layout() {
    let Some(rt) = runtime() else { return };
    let opts = SolveOpts::default();
    let mut px = Problem::build(grid2(), StencilKind::P7, 2);
    let mut xc = xla_for(&rt, &px);
    let sx = px.solve(Method::parse("cg").unwrap(), &opts, &mut xc);
    assert!(sx.converged);
    assert!(sx.x_error < 1e-5);
    // cross-check against native multi-rank
    let mut pn = Problem::build(grid2(), StencilKind::P7, 2);
    let sn = pn.solve(Method::parse("cg").unwrap(), &opts, &mut Native);
    assert_eq!(sn.iterations, sx.iterations);
}

#[test]
fn xla_primitives_match_native() {
    let Some(rt) = runtime() else { return };
    use hlam::solvers::Compute;
    let pb = Problem::build(grid1(), StencilKind::P7, 1);
    let sys = &pb.ranks[0].sys;
    let n = sys.n();
    let mut rng = hlam::util::Rng::new(99);
    let mut x_ext = sys.new_ext();
    for v in x_ext.iter_mut().take(n) {
        *v = rng.normal();
    }
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    let mut nat = Native;
    let mut xc = xla_for(&rt, &pb);

    // spmv
    let mut y1 = vec![0.0; n];
    let mut y2 = vec![0.0; n];
    nat.spmv(&sys.a, &x_ext, &mut y1, 0, n);
    xc.spmv(&sys.a, &x_ext, &mut y2, 0, n);
    for i in 0..n {
        assert!((y1[i] - y2[i]).abs() < 1e-11, "spmv row {i}");
    }
    // dot
    let d1 = nat.dot(&x_ext[..n], &y, 0, n);
    let d2 = xc.dot(&x_ext[..n], &y, 0, n);
    assert!((d1 - d2).abs() < 1e-9 * (1.0 + d1.abs()));
    // axpby
    let mut a1 = y.clone();
    let mut a2 = y.clone();
    nat.axpby(1.5, &x_ext[..n], -0.25, &mut a1, 0, n);
    xc.axpby(1.5, &x_ext[..n], -0.25, &mut a2, 0, n);
    for i in 0..n {
        assert!((a1[i] - a2[i]).abs() < 1e-12, "axpby {i}");
    }
    // waxpby
    let mut z1 = y.clone();
    let mut z2 = y.clone();
    nat.waxpby(0.5, &x_ext[..n], 2.0, &y1, -1.0, &mut z1, 0, n);
    xc.waxpby(0.5, &x_ext[..n], 2.0, &y1, -1.0, &mut z2, 0, n);
    for i in 0..n {
        assert!((z1[i] - z2[i]).abs() < 1e-11, "waxpby {i}");
    }
    // jacobi step
    let mut j1 = vec![0.0; n];
    let mut j2 = vec![0.0; n];
    let r1 = nat.jacobi_step(&sys.a, &sys.b, &x_ext, &mut j1, 0, n);
    let r2 = xc.jacobi_step(&sys.a, &sys.b, &x_ext, &mut j2, 0, n);
    assert!((r1 - r2).abs() < 1e-8 * (1.0 + r1.abs()));
    for i in 0..n {
        assert!((j1[i] - j2[i]).abs() < 1e-11, "jacobi {i}");
    }
    // partial-range calls fall back to the native kernels
    let mut y3 = vec![0.0; n];
    xc.spmv(&sys.a, &x_ext, &mut y3, 0, n / 2);
    for i in 0..n / 2 {
        assert!((y3[i] - y1[i]).abs() < 1e-11, "partial spmv row {i}");
    }
}

#[test]
fn runtime_rejects_wrong_halo_layout() {
    let Some(rt) = runtime() else { return };
    // n=512 w=7 exists with halo 0 and 64 — not with halo 7
    let err = XlaCompute::new(rt, 512, 7, 512 + 7 + 1);
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("halo layout"), "{msg}");
}

#[test]
fn manifest_lists_test_sizes() {
    let Some(rt) = runtime() else { return };
    let sizes = rt.sizes();
    assert!(sizes.contains(&(512, 7, 513)), "{sizes:?}");
    assert!(sizes.contains(&(512, 27, 513)));
    assert!(sizes.contains(&(512, 7, 577)));
}
