//! Acceptance contract of the RunSpec/Session API redesign:
//!
//!  * `Session::run` reproduces **bitwise identical** convergence
//!    histories to the legacy `Problem::solve` / `solve_with` /
//!    `solve_hybrid` entry points, for all 8 method variants ×
//!    {lockstep, threaded} transports × {seq, fork-join, task}
//!    executor strategies;
//!  * a `RunSpec` JSON emitted by one run replays to the same history;
//!  * the session's problem cache reuses one assembly across runs that
//!    share {grid, stencil, ranks} (same matrix pointer) with
//!    bitwise-identical stats vs a fresh assembly;
//!  * observers see exactly the history the stats report, for every
//!    method variant, and never change the numbers.

use std::sync::Mutex;

use hlam::api::{RunSpec, Session, SolveError, SpecError};
use hlam::exec::{ExecSpec, ExecStrategy, Executor};
use hlam::mesh::Grid3;
use hlam::simmpi::TransportKind;
use hlam::solvers::{Method, Native, Observer, Problem, SolveOpts, SolveStats};
use hlam::sparse::StencilKind;

const ALL_METHODS: [&str; 8] = [
    "jacobi",
    "gs",
    "gs-rb",
    "gs-relaxed",
    "cg",
    "cg-nb",
    "bicgstab",
    "bicgstab-b1",
];

const GRID: (usize, usize, usize) = (6, 6, 12);

fn grid() -> Grid3 {
    Grid3::new(GRID.0, GRID.1, GRID.2)
}

/// Per-method options mirroring `tests/integration_exec.rs` (the task GS
/// variants need explicit task blocks).
fn base_opts(method: &str) -> SolveOpts {
    let mut opts = SolveOpts::default();
    if method.starts_with("gs-") {
        opts.ntasks = 6;
        opts.task_order_seed = 3;
    }
    opts
}

fn spec_for(method: &str, strategy: ExecStrategy, transport: TransportKind) -> RunSpec {
    RunSpec::builder()
        .method_str(method)
        .grid(grid())
        .ranks(2)
        .exec(ExecSpec::new(strategy, 2))
        .transport(transport)
        .opts(base_opts(method))
        .build()
        .unwrap()
}

fn assert_identical(a: &SolveStats, b: &SolveStats, ctx: &str) {
    assert_eq!(a.iterations, b.iterations, "{ctx}: iteration count");
    assert_eq!(a.converged, b.converged, "{ctx}: convergence flag");
    assert_eq!(a.restarts, b.restarts, "{ctx}: restart count");
    assert_eq!(
        a.rel_residual.to_bits(),
        b.rel_residual.to_bits(),
        "{ctx}: final residual"
    );
    assert_eq!(a.x_error.to_bits(), b.x_error.to_bits(), "{ctx}: x error");
    assert_eq!(a.history.len(), b.history.len(), "{ctx}: history length");
    for (i, (ha, hb)) in a.history.iter().zip(&b.history).enumerate() {
        assert_eq!(ha.to_bits(), hb.to_bits(), "{ctx}: history[{i}] {ha} vs {hb}");
    }
}

// ---------------------------------------------------------------------
// Session vs every legacy entry point, full method × transport × exec
// sweep (the acceptance criterion)
// ---------------------------------------------------------------------

#[test]
fn session_bitwise_matches_legacy_paths_all_methods_transports_execs() {
    let mut session = Session::new();
    for method in ALL_METHODS {
        let m = Method::parse(method).unwrap();
        let opts = base_opts(method);

        // legacy path 1: Problem::solve (lockstep, shared backend, seq)
        let mut p1 = Problem::build(grid(), StencilKind::P7, 2);
        let reference = p1.solve(m, &opts, &mut Native);
        assert!(reference.converged, "{method}: reference did not converge");

        // legacy path 2: Problem::solve_with under an explicit executor
        let mut p2 = Problem::build(grid(), StencilKind::P7, 2);
        let with = p2.solve_with(m, &opts, &mut Native, &Executor::new(ExecStrategy::ForkJoin, 2));
        assert_identical(&reference, &with, &format!("{method}: solve_with"));

        for strategy in [ExecStrategy::Seq, ExecStrategy::ForkJoin, ExecStrategy::TaskPool] {
            // legacy path 3: Problem::solve_hybrid
            let mut p3 = Problem::build(grid(), StencilKind::P7, 2);
            let hybrid = p3.solve_hybrid(
                m,
                &opts,
                &ExecSpec::new(strategy, 2),
                TransportKind::Lockstep,
            );
            assert_identical(
                &reference,
                &hybrid,
                &format!("{method}: solve_hybrid {}", strategy.name()),
            );

            // the new API, both transports (one cached assembly for all
            // 48 runs of this sweep)
            for transport in [TransportKind::Lockstep, TransportKind::Threaded] {
                let spec = spec_for(method, strategy, transport);
                let got = session.run(&spec).unwrap();
                assert_identical(
                    &reference,
                    &got,
                    &format!(
                        "{method}: Session {} {}",
                        strategy.name(),
                        transport.name()
                    ),
                );
            }
        }
    }
    // the whole sweep shares {grid, stencil, ranks}: one assembly total
    assert_eq!(session.cached_problems(), 1);
}

// ---------------------------------------------------------------------
// Spec JSON replay
// ---------------------------------------------------------------------

#[test]
fn emitted_spec_json_replays_to_identical_history() {
    for method in ["cg-nb", "bicgstab-b1", "gs-relaxed"] {
        let spec = spec_for(method, ExecStrategy::TaskPool, TransportKind::Threaded);
        let mut s1 = Session::new();
        let original = s1.run(&spec).unwrap();

        // serialize → parse → identical spec → identical history in a
        // completely fresh session
        let text = spec.to_json_string();
        let replayed_spec = RunSpec::from_json_str(&text).unwrap();
        assert_eq!(replayed_spec, spec, "{method}: spec JSON round-trip");
        let mut s2 = Session::new();
        let replayed = s2.run(&replayed_spec).unwrap();
        assert_identical(&original, &replayed, &format!("{method}: JSON replay"));
    }
}

#[test]
fn spec_file_save_load_roundtrip() {
    let dir = std::env::temp_dir().join("hlam_it_api_spec");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.json");
    let spec = spec_for("cg", ExecStrategy::Seq, TransportKind::Lockstep);
    spec.save(&path).unwrap();
    let loaded = RunSpec::load(&path).unwrap();
    assert_eq!(loaded, spec);
    // a missing file is a structured I/O error, not a panic
    match RunSpec::load(dir.join("missing.json")) {
        Err(SolveError::Io { .. }) => {}
        other => panic!("expected Io error, got {other:?}"),
    }
}

// ---------------------------------------------------------------------
// Problem-cache reuse
// ---------------------------------------------------------------------

#[test]
fn session_cache_reuses_assembly_with_bitwise_identical_stats() {
    let spec = spec_for("cg", ExecStrategy::Seq, TransportKind::Lockstep);
    let mut session = Session::new();

    let first = session.run(&spec).unwrap();
    let ptr1 = session
        .assembly_ptr(spec.grid, spec.stencil, spec.ranks)
        .unwrap();
    let second = session.run(&spec).unwrap();
    let ptr2 = session
        .assembly_ptr(spec.grid, spec.stencil, spec.ranks)
        .unwrap();

    // same assembly object across runs...
    assert_eq!(ptr1, ptr2, "assembly was rebuilt between runs");
    assert_eq!(session.cached_problems(), 1);
    // ...and reuse is numerically invisible
    assert_identical(&first, &second, "cached rerun");

    // a different method on the same {grid, stencil, ranks} still reuses
    let spec_j = spec_for("jacobi", ExecStrategy::Seq, TransportKind::Lockstep);
    session.run(&spec_j).unwrap();
    assert_eq!(session.cached_problems(), 1);
    assert_eq!(
        session.assembly_ptr(spec.grid, spec.stencil, spec.ranks),
        Some(ptr1)
    );

    // a fresh assembly produces the same bits as the cached rerun
    let mut fresh = Problem::build(spec.grid, spec.stencil, spec.ranks);
    let from_fresh = fresh.solve_hybrid(spec.method, &spec.opts, &spec.exec, spec.transport);
    assert_identical(&from_fresh, &second, "fresh vs cached assembly");

    // changing any cache-key dimension assembles anew
    let spec_r4 = RunSpec::builder()
        .method_str("cg")
        .grid(grid())
        .ranks(4)
        .build()
        .unwrap();
    session.run(&spec_r4).unwrap();
    assert_eq!(session.cached_problems(), 2);
}

// ---------------------------------------------------------------------
// Observer: history equivalence for all 8 variants + early stop
// ---------------------------------------------------------------------

/// Records rank 0's per-iteration relative residuals.
struct Recorder {
    rank0: Mutex<Vec<f64>>,
    allreduces: Mutex<usize>,
    finished_ranks: Mutex<Vec<usize>>,
}

impl Recorder {
    fn new() -> Self {
        Recorder {
            rank0: Mutex::new(Vec::new()),
            allreduces: Mutex::new(0),
            finished_ranks: Mutex::new(Vec::new()),
        }
    }
}

impl Observer for Recorder {
    fn on_iteration(&self, rank: usize, _iteration: usize, rel_residual: f64) {
        if rank == 0 {
            self.rank0.lock().unwrap().push(rel_residual);
        }
    }

    fn on_allreduce(&self, _rank: usize, _tag: u64, _values: &[f64]) {
        *self.allreduces.lock().unwrap() += 1;
    }

    fn on_finish(&self, rank: usize, _stats: &SolveStats) {
        self.finished_ranks.lock().unwrap().push(rank);
    }
}

#[test]
fn observer_sees_exactly_the_reported_history_all_methods() {
    for method in ALL_METHODS {
        for transport in [TransportKind::Lockstep, TransportKind::Threaded] {
            let spec = spec_for(method, ExecStrategy::Seq, transport);
            let mut session = Session::new();
            let obs = Recorder::new();
            let stats = session.run_observed(&spec, &obs).unwrap();
            let ctx = format!("{method} / {}", transport.name());

            let seen = obs.rank0.into_inner().unwrap();
            assert_eq!(seen.len(), stats.history.len(), "{ctx}: callback count");
            for (i, (a, b)) in seen.iter().zip(&stats.history).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: entry {i}");
            }
            // every rank finished exactly once
            let mut fins = obs.finished_ranks.into_inner().unwrap();
            fins.sort_unstable();
            assert_eq!(fins, vec![0, 1], "{ctx}: finish callbacks");
            // allreduce taps fired (both ranks, >= one per iteration)
            let ars = obs.allreduces.into_inner().unwrap();
            assert!(ars >= 2 * stats.iterations, "{ctx}: {ars} allreduce taps");

            // and observing never changes the numbers
            let mut plain = Session::new();
            let unobserved = plain.run(&spec).unwrap();
            assert_identical(&unobserved, &stats, &format!("{ctx}: observer purity"));
        }
    }
}

/// Stops every run after 3 recorded iterations (a pure function of the
/// iteration number, as the Observer contract requires).
struct StopAt3;

impl Observer for StopAt3 {
    fn stop(&self, iteration: usize, _rel_residual: f64) -> bool {
        iteration >= 3
    }
}

#[test]
fn observer_early_stop_is_honoured_on_both_transports() {
    for method in ["cg", "jacobi", "bicgstab-b1"] {
        for transport in [TransportKind::Lockstep, TransportKind::Threaded] {
            let mut spec = spec_for(method, ExecStrategy::Seq, transport);
            spec.opts.eps = 1e-300; // effectively unreachable: the stop hook ends the run
            let mut session = Session::new();
            let stats = session.run_observed(&spec, &StopAt3).unwrap();
            let ctx = format!("{method} / {}", transport.name());
            assert!(!stats.converged, "{ctx}: must stop before convergence");
            assert_eq!(stats.history.len(), 3, "{ctx}: history length");
        }
    }
}

// ---------------------------------------------------------------------
// Structured errors end to end
// ---------------------------------------------------------------------

#[test]
fn bad_input_yields_structured_errors_with_suggestions() {
    // unknown method, close to a valid one -> suggestion
    let err = RunSpec::builder().method_str("cgg").build().unwrap_err();
    match &err {
        SpecError::Unknown {
            what, suggestion, ..
        } => {
            assert_eq!(*what, "method");
            assert_eq!(*suggestion, Some("cg"));
        }
        other => panic!("expected Unknown, got {other:?}"),
    }
    assert!(err.to_string().contains("did you mean 'cg'"), "{err}");

    // misspelled transport / strategy / backend / stencil
    assert!(RunSpec::builder().transport_str("lockstp").build().is_err());
    assert!(RunSpec::builder().strategy_str("forkjion").build().is_err());
    assert!(RunSpec::builder().backend_str("navite").build().is_err());
    assert!(RunSpec::builder().stencil_str("9").build().is_err());

    // malformed grids never panic
    for bad in ["", "8", "8x8", "8x8x", "ax8x8", "8x0x8", "8x8x8x8"] {
        assert!(
            matches!(
                RunSpec::builder().grid_str(bad).build(),
                Err(SpecError::BadGrid { .. })
            ),
            "grid '{bad}' must be rejected"
        );
    }

    // a session rejects invalid hand-built specs before running
    let mut session = Session::new();
    let mut spec = RunSpec::builder().build().unwrap();
    spec.ranks = 10_000; // far more ranks than z-planes
    match session.run(&spec) {
        Err(SolveError::Spec(SpecError::Invalid { field, .. })) => assert_eq!(field, "ranks"),
        other => panic!("expected spec error, got {other:?}"),
    }
}

#[test]
fn multisplit_is_listed_and_suggested() {
    // regression: `Method::parse` accepted "multisplit" while the
    // did-you-mean candidate list stopped at the 8 classic variants, so
    // a near-miss typo never suggested it. `Method::ALL_NAMES` is now
    // the single pinned list of every parseable canonical name.
    assert!(Method::ALL_NAMES.contains(&"multisplit"));
    for name in Method::ALL_NAMES {
        let m: Method = name.parse().unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(m.name(), name, "canonical names round-trip");
    }
    // the classic-variant list used by sweeps stays a strict subset
    for name in Method::NAMES {
        assert!(Method::ALL_NAMES.contains(&name), "{name} missing");
    }
    assert_eq!(Method::ALL_NAMES.len(), Method::NAMES.len() + 1);

    let err = "multisplt".parse::<Method>().unwrap_err();
    match &err {
        SpecError::Unknown { suggestion, .. } => {
            assert_eq!(*suggestion, Some("multisplit"));
        }
        other => panic!("expected Unknown, got {other:?}"),
    }
    assert!(
        err.to_string().contains("did you mean 'multisplit'"),
        "{err}"
    );
}
