//! Steady-state allocation accounting — the enforcement arm of the
//! plan-once / run-many refactor (DESIGN.md §7).
//!
//! A counting `#[global_allocator]` wraps the system allocator; an
//! observer snapshots the allocation counter at the end of every solver
//! iteration. After a warm-up window (plan caches filling, buffer
//! capacities settling, ISODD mailbox/reduction keys appearing — all
//! done within the first few iterations), the delta between consecutive
//! iterations must be **zero** on the `seq` strategy and within a small
//! fixed bound on `fork-join` / `task` (their kernels and scheduling are
//! allocation-free too; the bound only absorbs OS-level lazy
//! initialisation noise).
//!
//! Everything lives in ONE `#[test]` so no concurrent test case can
//! perturb the process-wide counter mid-measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use hlam::exec::{ExecSpec, ExecStrategy};
use hlam::mesh::Grid3;
use hlam::simmpi::TransportKind;
use hlam::solvers::{Method, Observer, PrecondKind, Problem, SolveOpts};
use hlam::sparse::{KernelKind, StencilKind};

/// System allocator with a process-wide allocation counter (`alloc` and
/// `realloc` count; frees don't — growth is what steady state forbids).
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const ITERS: usize = 10;
/// Iterations 1..=WARMUP may allocate (plan caches, buffer capacities,
/// first-use transport keys); everything after must be steady.
const WARMUP: usize = 4;

/// Snapshots the allocation counter at the end of each iteration.
struct AllocProbe {
    at_iteration: [AtomicUsize; ITERS + 1],
}

impl Default for AllocProbe {
    fn default() -> Self {
        AllocProbe::new()
    }
}

impl AllocProbe {
    fn new() -> Self {
        AllocProbe {
            at_iteration: std::array::from_fn(|_| AtomicUsize::new(0)),
        }
    }

    /// Allocations during steady-state iteration `i` (WARMUP < i <= ITERS).
    fn delta(&self, i: usize) -> usize {
        self.at_iteration[i].load(Ordering::SeqCst)
            - self.at_iteration[i - 1].load(Ordering::SeqCst)
    }
}

impl Observer for AllocProbe {
    fn on_iteration(&self, rank: usize, iteration: usize, _rel: f64) {
        if rank == 0 && iteration <= ITERS {
            self.at_iteration[iteration].store(ALLOCS.load(Ordering::SeqCst), Ordering::SeqCst);
        }
    }
}

#[test]
fn steady_state_iterations_do_not_allocate() {
    // 32³ rows split into 8 chunks of DEFAULT_CHUNK_ROWS — the parallel
    // strategies genuinely engage. eps = 0 never converges, so the run
    // performs exactly ITERS full iterations. The 2-rank case exercises
    // the transport steady state too (halo staging gather, message
    // buffer recycling, allreduce round pooling), with a tiny slack
    // because the counter is process-wide and both rank threads land in
    // it.
    // The overlap rows re-run the multi-rank shapes with the halo
    // exchange split into start → interior → finish → boundary: the
    // overlapped steady-state iteration must stay within the exact same
    // bounds (the overlap path reuses the cached chunk plans, the
    // workspace partials buffer and the recycled transport buffers — it
    // introduces no per-iteration allocation of its own).
    let grid = Grid3::new(32, 32, 32);
    let opts = SolveOpts {
        eps: 0.0,
        max_iters: ITERS,
        ..SolveOpts::default()
    };
    // The second pass re-runs every shape on the matrix-free stencil
    // backend: its StencilOp is prebuilt by the generator and
    // `set_kernel(Stencil)` only flips the dispatch switch, so the
    // steady-state bounds must hold unchanged there too.
    for kernel in [KernelKind::Ell, KernelKind::Stencil] {
        for (strategy, threads, ranks, overlap, bound) in [
            (ExecStrategy::Seq, 1usize, 1usize, false, 0usize),
            (ExecStrategy::Seq, 1, 2, false, 2),
            (ExecStrategy::ForkJoin, 4, 1, false, 8),
            (ExecStrategy::TaskPool, 4, 1, false, 8),
            (ExecStrategy::Seq, 1, 2, true, 2),
            (ExecStrategy::ForkJoin, 4, 2, true, 8),
            (ExecStrategy::TaskPool, 4, 2, true, 8),
        ] {
            let mut pb = Problem::build(grid, StencilKind::P7, ranks);
            pb.set_kernel(kernel);
            let probe = AllocProbe::new();
            let spec = ExecSpec::new(strategy, threads).with_overlap(overlap);
            let stats = pb.solve_hybrid_observed(
                Method::parse("cg").unwrap(),
                &opts,
                &spec,
                TransportKind::Lockstep,
                &probe,
            );
            assert_eq!(stats.iterations, ITERS, "{strategy:?}: must run all iters");
            if overlap && ranks > 1 {
                assert!(
                    pb.stats.overlapped_rows > 0,
                    "{strategy:?}: overlap run did no overlapped work"
                );
            }
            for i in (WARMUP + 1)..=ITERS {
                let d = probe.delta(i);
                assert!(
                    d <= bound,
                    "{} kernel={} threads={threads} ranks={ranks} overlap={overlap}: \
                     iteration {i} performed {d} heap allocations (allowed {bound}) — \
                     the zero-allocation steady state regressed",
                    strategy.name(),
                    kernel.name(),
                );
            }
        }
    }

    // Preconditioned CG (DESIGN.md §10): every M⁻¹ apply runs through
    // the same cached chunk plans and the preallocated z/d/q workspace
    // vectors in RankState, so the steady-state bounds hold unchanged —
    // the preconditioner tier adds no per-iteration allocation.
    for (precond, inner) in [
        (PrecondKind::Jacobi, 2),
        (PrecondKind::BlockJacobi, 2),
        (PrecondKind::Chebyshev, 3),
    ] {
        let popts = SolveOpts {
            eps: 0.0,
            max_iters: ITERS,
            precond,
            inner_iters: inner,
            ..SolveOpts::default()
        };
        for (strategy, threads, ranks, overlap, bound) in [
            (ExecStrategy::Seq, 1usize, 1usize, false, 0usize),
            (ExecStrategy::Seq, 1, 2, true, 2),
            (ExecStrategy::TaskPool, 4, 2, true, 8),
        ] {
            let mut pb = Problem::build(grid, StencilKind::P7, ranks);
            let probe = AllocProbe::new();
            let spec = ExecSpec::new(strategy, threads).with_overlap(overlap);
            let stats = pb.solve_hybrid_observed(
                Method::parse("cg").unwrap(),
                &popts,
                &spec,
                TransportKind::Lockstep,
                &probe,
            );
            assert_eq!(
                stats.iterations, ITERS,
                "pcg/{}: must run all iters",
                precond.name()
            );
            for i in (WARMUP + 1)..=ITERS {
                let d = probe.delta(i);
                assert!(
                    d <= bound,
                    "pcg precond={} {} threads={threads} ranks={ranks} overlap={overlap}: \
                     iteration {i} performed {d} heap allocations (allowed {bound}) — \
                     the preconditioned zero-allocation steady state regressed",
                    precond.name(),
                    strategy.name(),
                );
            }
        }
    }

    // Checkpointed + scrubbed solves (DESIGN.md §13): the first capture
    // allocates the snapshot buffers (history reserved to `max_iters` up
    // front) and the first true-residual scrub warms its kernel plans —
    // at cadence 2 both land inside the warm-up window — after which
    // snapshot refills go through the capacity-retaining `stage_copy`
    // idiom and scrubs reuse the solve's own dead buffers: the
    // steady-state bounds hold unchanged with recovery armed.
    let copts = SolveOpts {
        eps: 0.0,
        max_iters: ITERS,
        checkpoint_every: 2,
        scrub_every: 2,
        ..SolveOpts::default()
    };
    for method in ["jacobi", "cg", "bicgstab"] {
        for (strategy, threads, ranks, overlap, bound) in [
            (ExecStrategy::Seq, 1usize, 1usize, false, 0usize),
            (ExecStrategy::Seq, 1, 2, true, 2),
            (ExecStrategy::TaskPool, 4, 2, true, 8),
        ] {
            let mut pb = Problem::build(grid, StencilKind::P7, ranks);
            let probe = AllocProbe::new();
            let spec = ExecSpec::new(strategy, threads).with_overlap(overlap);
            let stats = pb.solve_hybrid_observed(
                Method::parse(method).unwrap(),
                &copts,
                &spec,
                TransportKind::Lockstep,
                &probe,
            );
            assert_eq!(stats.iterations, ITERS, "{method}: must run all iters");
            assert!(
                stats.checkpoints >= ITERS / 2,
                "{method}: cadence 2 must keep capturing"
            );
            for i in (WARMUP + 1)..=ITERS {
                let d = probe.delta(i);
                assert!(
                    d <= bound,
                    "ckpt {method} {} threads={threads} ranks={ranks} overlap={overlap}: \
                     iteration {i} performed {d} heap allocations (allowed {bound}) — \
                     the checkpointed zero-allocation steady state regressed",
                    strategy.name(),
                );
            }
        }
    }
}
