//! Cross-module integration: distributed solvers over simmpi on larger
//! grids, convergence orderings between methods (the paper's qualitative
//! structure), restart ablation (D4), and decomposition invariance.
//! Runs go through the `api::Session` front-end (the `RunSpec` path is
//! bitwise identical to the legacy `Problem::solve` these tests
//! originally used — asserted by `tests/integration_api.rs`).

use hlam::api::{RunSpec, Session};
use hlam::mesh::Grid3;
use hlam::solvers::SolveOpts;
use hlam::sparse::StencilKind;
use hlam::util::proptest::forall;

fn solve(method: &str, grid: Grid3, kind: StencilKind, nranks: usize, opts: &SolveOpts) -> hlam::solvers::SolveStats {
    let spec = RunSpec::builder()
        .method_str(method)
        .grid(grid)
        .stencil(kind)
        .ranks(nranks)
        .opts(opts.clone())
        .build()
        .expect("test spec is valid");
    Session::new().run(&spec).expect("native run succeeds")
}

fn abs_opts() -> SolveOpts {
    SolveOpts {
        eps_absolute: true,
        ..SolveOpts::default()
    }
}

#[test]
fn paper_iteration_ordering_7pt() {
    // §4.1 one-node counts: BiCGStab 8 < GS 9 < CG 12 < Jacobi 18.
    let g = Grid3::new(16, 16, 32);
    let opts = abs_opts();
    let bi = solve("bicgstab", g, StencilKind::P7, 2, &opts).iterations;
    let gs = solve("gs", g, StencilKind::P7, 2, &opts).iterations;
    let cg = solve("cg", g, StencilKind::P7, 2, &opts).iterations;
    let ja = solve("jacobi", g, StencilKind::P7, 2, &opts).iterations;
    assert!(bi <= gs && gs <= cg && cg <= ja, "bi={bi} gs={gs} cg={cg} jacobi={ja}");
    // and the magnitudes are in the paper's neighbourhood
    // reduced grid => smaller ||b|| => slightly fewer absolute-eps orders
    assert!((4..=12).contains(&bi), "bicgstab {bi} (paper 8)");
    assert!((8..=16).contains(&cg), "cg {cg} (paper 12)");
    assert!((12..=24).contains(&ja), "jacobi {ja} (paper 18)");
}

#[test]
fn paper_iteration_regime_27pt() {
    // §4.1: the 27-pt system is weakly dominant — dramatically slower.
    let g = Grid3::new(12, 12, 24);
    let opts = abs_opts();
    let ja7 = solve("jacobi", g, StencilKind::P7, 2, &opts).iterations;
    let ja27 = solve("jacobi", g, StencilKind::P27, 2, &opts).iterations;
    assert!(ja27 > 8 * ja7, "27pt {ja27} vs 7pt {ja7}");
    let cg27 = solve("cg", g, StencilKind::P27, 2, &opts).iterations;
    let cg7 = solve("cg", g, StencilKind::P7, 2, &opts).iterations;
    assert!(cg27 > 2 * cg7, "27pt {cg27} vs 7pt {cg7}");
}

#[test]
fn decomposition_invariance_krylov() {
    // CG/BiCGStab iterates are decomposition-independent (same reduction
    // tree in simmpi): identical counts for 1..5 ranks.
    let g = Grid3::new(8, 8, 20);
    let opts = SolveOpts::default();
    let base = solve("cg", g, StencilKind::P7, 1, &opts).iterations;
    for nranks in [2, 4, 5] {
        let it = solve("cg", g, StencilKind::P7, nranks, &opts).iterations;
        assert_eq!(it, base, "nranks={nranks}");
    }
}

#[test]
fn gs_processor_local_depends_weakly_on_ranks() {
    // processor-localised GS uses stale boundary values: more ranks may
    // shift the count slightly but must stay close and converge.
    let g = Grid3::new(8, 8, 24);
    let opts = abs_opts();
    let i1 = solve("gs", g, StencilKind::P7, 1, &opts);
    let i4 = solve("gs", g, StencilKind::P7, 4, &opts);
    assert!(i1.converged && i4.converged);
    assert!(
        (i1.iterations as i64 - i4.iterations as i64).abs() <= 3,
        "1 rank {} vs 4 ranks {}",
        i1.iterations,
        i4.iterations
    );
}

#[test]
fn bicgstab_restart_ablation_d4() {
    // D4: with restart disabled (threshold 0) and adversarial task
    // ordering, B1 may need more iterations or fail to converge as
    // fast; with the paper's restart it stays robust.
    let g = Grid3::new(8, 8, 16);
    let mut with = abs_opts();
    with.ntasks = 32;
    with.task_order_seed = 5;
    let mut without = with.clone();
    without.restart_eps = 0.0;
    without.max_iters = 400;
    let s_with = solve("bicgstab-b1", g, StencilKind::P27, 2, &with);
    let s_without = solve("bicgstab-b1", g, StencilKind::P27, 2, &without);
    assert!(s_with.converged);
    // restart never hurts: iterations(with) <= iterations(without) + 2
    assert!(
        s_with.iterations <= s_without.iterations + 2,
        "with {} vs without {}",
        s_with.iterations,
        s_without.iterations
    );
}

#[test]
fn task_order_seeds_perturb_bicgstab_count() {
    // §3.3: task execution order perturbs reductions; BiCGStab counts may
    // move by a few iterations across seeds, but every seed converges.
    let g = Grid3::new(8, 8, 16);
    let mut counts = Vec::new();
    for seed in [1u64, 2, 3, 4] {
        let mut opts = abs_opts();
        opts.ntasks = 32;
        opts.task_order_seed = seed;
        let s = solve("bicgstab-b1", g, StencilKind::P27, 2, &opts);
        assert!(s.converged, "seed {seed}");
        assert!(s.x_error < 1e-4);
        counts.push(s.iterations);
    }
    let spread = counts.iter().max().unwrap() - counts.iter().min().unwrap();
    assert!(spread <= 6, "counts {counts:?}");
}

#[test]
fn property_every_method_converges_on_random_grids() {
    forall(
        31415,
        12,
        |r, _| {
            let nx = 3 + r.below(6);
            let ny = 3 + r.below(6);
            let nz = 6 + r.below(12);
            let nranks = 1 + r.below(3.min(nz / 2));
            let method = ["cg", "cg-nb", "bicgstab", "bicgstab-b1", "jacobi", "gs", "gs-relaxed"]
                [r.below(7)];
            (nx, ny, nz, nranks, method)
        },
        |&(nx, ny, nz, nranks, method)| {
            let mut opts = SolveOpts::default();
            if method.starts_with("gs-") {
                opts.ntasks = 4;
                opts.task_order_seed = 3;
            }
            let s = solve(method, Grid3::new(nx, ny, nz), StencilKind::P7, nranks, &opts);
            s.converged && s.x_error < 1e-3
        },
    );
}

#[test]
fn residual_histories_monotone_for_stationary_methods() {
    // Jacobi/GS on a dominant system contract monotonically.
    let g = Grid3::new(8, 8, 16);
    for method in ["jacobi", "gs"] {
        let s = solve(method, g, StencilKind::P7, 2, &SolveOpts::default());
        for w in s.history.windows(2) {
            assert!(w[1] <= w[0] * 1.0001, "{method}: {} -> {}", w[0], w[1]);
        }
    }
}

#[test]
fn x_error_tracks_epsilon() {
    // tighter eps -> smaller solution error
    let g = Grid3::new(8, 8, 16);
    let loose = SolveOpts {
        eps: 1e-4,
        ..SolveOpts::default()
    };
    let tight = SolveOpts {
        eps: 1e-10,
        ..SolveOpts::default()
    };
    let sl = solve("cg", g, StencilKind::P7, 1, &loose);
    let st = solve("cg", g, StencilKind::P7, 1, &tight);
    assert!(st.x_error < sl.x_error);
}
