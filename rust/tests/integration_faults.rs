//! Acceptance contract of the failure taxonomy + deterministic fault
//! injection (ISSUE 9):
//!
//!  * injected transport faults surface as structured
//!    [`SolveError::TransportFailure`] values naming the originating
//!    rank and phase — never as a process abort — on both transports;
//!  * a threaded rank stalled past the configured `deadlock_timeout_ms`
//!    is diagnosed as a timeout, while the lockstep oracle (which
//!    serialises ranks and therefore cannot time out) completes the
//!    same plan;
//!  * corrupted allreduce payloads trip the solver guards into the
//!    structured taxonomy (`non-finite` / `solver-breakdown`), and the
//!    verdict is identical on every replay;
//!  * BiCGStab's deterministic breakdown restart turns an injected
//!    breakdown into a converged solve once `SolveOpts::restarts`
//!    grants budget;
//!  * faults that only perturb *timing* (delayed allreduce posts) leave
//!    convergence histories bitwise identical to the fault-free run;
//!  * a seeded chaos plan replays to the identical outcome, Ok or Err;
//!  * the solve service drains a chaos trace (≥25 % injected failures,
//!    including raw panics) with exactly one structured response per
//!    request, bitwise-identical results for the fault-free jobs, and
//!    telemetry that accounts for every panic, retry, and deadline.
//!
//! Rollback recovery contract (ISSUE 10):
//!
//!  * enabling `checkpoint_every` / `scrub_every` without a fault leaves
//!    clean histories bitwise identical to the knobs-off run;
//!  * an injected silent corruption (finite skew, checksum lane intact)
//!    is detected by the duplicate-fold guard and healed by rolling back
//!    to the latest rank-consistent snapshot — the recovered history is
//!    bitwise identical to the uninterrupted run, on both transports and
//!    every shared-memory strategy;
//!  * a transport abort recovers the same way once a snapshot exists;
//!  * observer callback counts prove only the post-checkpoint sliver
//!    re-executes (no cold restart hiding inside the retry loop);
//!  * the service salvages snapshots across a worker panic and warm-
//!    resumes the requeued job to a bitwise-clean result, with the
//!    rollback telemetry accounting for every resume.

use std::sync::atomic::{AtomicUsize, Ordering};

use hlam::api::{RunSpec, Session, SolveError};
use hlam::exec::{ExecSpec, ExecStrategy};
use hlam::mesh::Grid3;
use hlam::service::{history_digest, Response, Service, ServiceConfig, SolveRequest};
use hlam::simmpi::{Fault, FaultKind, FaultPlan, TransportKind};
use hlam::solvers::Observer;

/// A small 2-rank spec with one explicit fault installed.
fn faulty_spec(
    method: &str,
    transport: TransportKind,
    kind: FaultKind,
    rank: usize,
    at: usize,
    delay_ms: u64,
) -> RunSpec {
    RunSpec::builder()
        .method_str(method)
        .grid(Grid3::new(6, 6, 8))
        .ranks(2)
        .transport(transport)
        .push_fault(Fault {
            kind,
            rank,
            at,
            delay_ms,
        })
        .build()
        .expect("fault spec builds")
}

#[test]
fn injected_abort_surfaces_as_structured_transport_failure() {
    for transport in [TransportKind::Lockstep, TransportKind::Threaded] {
        let spec = faulty_spec("cg", transport, FaultKind::Abort, 1, 2, 0);
        let err = Session::new()
            .run(&spec)
            .expect_err("an aborted rank cannot produce a clean solve");
        match &err {
            SolveError::TransportFailure { rank, what, .. } => {
                // primary-failure selection reports the *originating*
                // abort, not the peer-echo failures it causes on rank 0
                assert_eq!(*rank, 1, "{transport:?}: wrong originating rank");
                assert_eq!(what, "injected abort", "{transport:?}");
            }
            other => panic!("{transport:?}: expected transport failure, got {other:?}"),
        }
    }
}

#[test]
fn threaded_stall_times_out_while_lockstep_completes() {
    let stalled = |transport| {
        let mut spec = faulty_spec("cg", transport, FaultKind::Stall, 0, 2, 150);
        // rank 1 blocks on rank 0's contribution; the 150 ms stall per
        // wait must overrun this window decisively
        spec.deadlock_timeout_ms = 40;
        spec
    };
    let err = Session::new()
        .run(&stalled(TransportKind::Threaded))
        .expect_err("a stalled threaded rank must be diagnosed, not waited out");
    assert!(
        matches!(err, SolveError::TransportFailure { .. }),
        "expected a transport timeout, got {err:?}"
    );
    // lockstep serialises ranks, so a stall is slow but never stuck:
    // the same plan (same timeout knob) completes and converges
    let stats = Session::new()
        .run(&stalled(TransportKind::Lockstep))
        .expect("lockstep survives a pure stall");
    assert!(stats.converged);
}

#[test]
fn corrupted_allreduce_fails_structurally_and_identically_on_replay() {
    let spec = faulty_spec(
        "cg",
        TransportKind::Lockstep,
        FaultKind::CorruptAllreduce,
        0,
        1,
        0,
    );
    let verdict = |spec: &RunSpec| {
        let err = Session::new()
            .run(spec)
            .expect_err("NaN lanes in an allreduce cannot converge");
        assert!(
            matches!(
                err,
                SolveError::NonFinite { .. }
                    | SolveError::Breakdown { .. }
                    | SolveError::Diverged { .. }
            ),
            "corruption must land in the solver taxonomy, got {err:?}"
        );
        err.to_string()
    };
    assert_eq!(verdict(&spec), verdict(&spec), "verdict must replay");
}

#[test]
fn bicgstab_restart_recovers_from_an_injected_breakdown() {
    let spec_at = |at: usize, restarts: usize| {
        let mut spec = faulty_spec(
            "bicgstab",
            TransportKind::Lockstep,
            FaultKind::CorruptAllreduce,
            0,
            at,
            0,
        );
        spec.grid = Grid3::new(8, 8, 16);
        spec.opts.restarts = restarts;
        spec
    };
    // scan the first few allreduce ordinals for one whose corruption
    // lands in a guarded Krylov denominator (ρ, r'·Ap, ω) — the NaN is
    // indistinguishable from a true breakdown to the guard
    let broken_at = (0..8).find(|&at| {
        matches!(
            Session::new().run(&spec_at(at, 0)),
            Err(SolveError::Breakdown { .. })
        )
    });
    let at = broken_at.expect("some early allreduce ordinal must hit a breakdown guard");
    // the same fault with restart budget: the reseed consumes the
    // poisoned direction and the solve completes cleanly
    let stats = Session::new()
        .run(&spec_at(at, 3))
        .expect("restart budget must absorb the injected breakdown");
    assert!(stats.converged, "restarted solve must converge");
    assert!(stats.restarts >= 1, "recovery must be via restart");
}

#[test]
fn delay_faults_leave_histories_bitwise_identical() {
    let base = |kind: Option<FaultKind>| {
        let mut b = RunSpec::builder()
            .method_str("cg")
            .grid(Grid3::new(6, 6, 8))
            .ranks(2)
            .transport(TransportKind::Threaded);
        if let Some(kind) = kind {
            b = b.push_fault(Fault {
                kind,
                rank: 1,
                at: 1,
                delay_ms: 30,
            });
        }
        b.build().unwrap()
    };
    let clean = Session::new().run(&base(None)).expect("clean run");
    for kind in [FaultKind::DelayAllreduce, FaultKind::Stall] {
        let slowed = Session::new()
            .run(&base(Some(kind)))
            .expect("timing faults do not fail a solve");
        assert_eq!(
            history_digest(&slowed.history),
            history_digest(&clean.history),
            "{kind:?} must not perturb numerics"
        );
        assert_eq!(
            slowed.rel_residual.to_bits(),
            clean.rel_residual.to_bits(),
            "{kind:?} must not perturb the final residual"
        );
    }
}

#[test]
fn seeded_chaos_plans_replay_identically_across_methods_and_transports() {
    let outcome = |spec: &RunSpec| match Session::new().run(spec) {
        Ok(stats) => format!(
            "ok:{}:{:016x}",
            stats.history.len(),
            history_digest(&stats.history)
        ),
        Err(e) => format!("err:{e}"),
    };
    // the matrix spans the plain classic loops, two-stage multisplit,
    // and the preconditioned classic variants — chaos must replay
    // identically whatever inner machinery the method drags in
    for (method, precond) in [
        ("cg", "none"),
        ("bicgstab", "none"),
        ("multisplit", "none"),
        ("cg", "jacobi"),
        ("bicgstab", "block-jacobi"),
    ] {
        for transport in [TransportKind::Lockstep, TransportKind::Threaded] {
            for seed in 1..=3u64 {
                let spec = RunSpec::builder()
                    .method_str(method)
                    .precond_str(precond)
                    .grid(Grid3::new(6, 6, 8))
                    .ranks(2)
                    .transport(transport)
                    .fault(FaultPlan {
                        seed,
                        faults: Vec::new(),
                    })
                    .build()
                    .unwrap();
                let first = outcome(&spec);
                assert_eq!(
                    first,
                    outcome(&spec),
                    "{method}+{precond}/{transport:?}: chaos seed {seed} must replay"
                );
                // the derived chaos plan never injects a raw panic, so
                // every outcome is structured: a clean solve (timing
                // faults) or a taxonomy error — never a process abort
                assert!(
                    first.starts_with("ok:") || first.starts_with("err:"),
                    "{first}"
                );
            }
        }
    }
}

#[test]
fn service_chaos_drain_answers_every_request_exactly_once() {
    const JOBS: usize = 16;
    let clean = RunSpec::builder()
        .method_str("cg")
        .grid(Grid3::new(6, 6, 8))
        .ranks(2)
        .build()
        .unwrap();
    let reference = Session::new().run(&clean).expect("reference solve");
    let ref_digest = history_digest(&reference.history);

    let with_fault = |kind: FaultKind, rank: usize| {
        let mut spec = clean.clone();
        spec.fault.faults.push(Fault {
            kind,
            rank,
            at: 2,
            delay_ms: 0,
        });
        spec
    };
    let service = Service::start(ServiceConfig {
        workers: 2,
        total_threads: 4,
        queue_cap: JOBS,
        default_iter_budget: None,
        exec_cache_sets: 4,
        default_deadline_ms: None,
        max_retries: 1,
    });
    // 75 % injected failures (≥ the 25 % the acceptance bar asks for):
    // raw panics, structured aborts, corrupted numerics, then clean
    for i in 0..JOBS {
        let spec = match i % 4 {
            0 => with_fault(FaultKind::Panic, 0),
            1 => with_fault(FaultKind::Abort, 1),
            2 => with_fault(FaultKind::CorruptAllreduce, 0),
            _ => clean.clone(),
        };
        service.submit(
            SolveRequest {
                id: Some(format!("c-{i}")),
                spec,
                iter_budget: None,
                deadline_ms: None,
            },
            None,
        );
    }
    let responses = service.drain();
    let counters = service.shutdown();

    assert_eq!(responses.len(), JOBS, "exactly one response per request");
    let mut ids: Vec<&str> = responses.iter().map(Response::id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), JOBS, "no duplicate responses");

    for i in 0..JOBS {
        let id = format!("c-{i}");
        let resp = responses.iter().find(|r| r.id() == id).unwrap();
        match i % 4 {
            0 => match resp {
                // a panicking job is retried once on a rebuilt session,
                // panics again (the fault is in the spec), and only the
                // final attempt answers
                Response::Error { code, reason, .. } => {
                    assert_eq!(*code, "internal-panic", "{id}");
                    assert!(reason.contains("attempt 2"), "{id}: {reason}");
                    assert!(reason.contains("injected panic"), "{id}: {reason}");
                }
                other => panic!("{id}: expected internal-panic, got {other:?}"),
            },
            1 => match resp {
                Response::Error { code, reason, .. } => {
                    assert_eq!(*code, "transport", "{id}");
                    assert!(reason.contains("injected abort"), "{id}: {reason}");
                }
                other => panic!("{id}: expected transport error, got {other:?}"),
            },
            2 => match resp {
                Response::Error { code, .. } => {
                    assert!(
                        ["non-finite", "solver-breakdown", "diverged"].contains(code),
                        "{id}: corrupted numerics must land in the taxonomy, got {code}"
                    );
                }
                other => panic!("{id}: expected solver error, got {other:?}"),
            },
            _ => {
                let ok = resp
                    .as_ok()
                    .unwrap_or_else(|| panic!("{id}: clean job failed: {resp:?}"));
                // chaos on neighbouring jobs must not leak into clean
                // results — bitwise identical to the single-shot run
                assert_eq!(ok.history_digest, ref_digest, "{id}");
                assert_eq!(ok.rel_residual_bits, reference.rel_residual.to_bits(), "{id}");
            }
        }
    }
    let quarter = (JOBS / 4) as u64;
    assert_eq!(counters.completed, quarter, "clean jobs");
    assert_eq!(counters.errors, 3 * quarter, "faulted jobs");
    assert_eq!(counters.retried, quarter, "each panic job retried once");
    assert_eq!(counters.panics, 2 * quarter, "both attempts panicked");
    assert_eq!(counters.deadlines, 0);
    assert_eq!(counters.accepted, JOBS as u64);
}

#[test]
fn expired_deadline_answers_with_the_deadline_code() {
    let service = Service::start(ServiceConfig {
        workers: 1,
        total_threads: 2,
        queue_cap: 4,
        default_iter_budget: None,
        exec_cache_sets: 4,
        default_deadline_ms: None,
        max_retries: 1,
    });
    let mut spec = RunSpec::default();
    spec.grid = Grid3::new(6, 6, 8);
    service.submit(
        SolveRequest {
            id: Some("late".to_string()),
            spec,
            iter_budget: None,
            // already expired on arrival: the memoised deadline observer
            // stops the solve at its first verdict and the job answers
            // with the deadline code instead of a partial ok
            deadline_ms: Some(0),
        },
        None,
    );
    let responses = service.drain();
    let counters = service.shutdown();
    assert_eq!(responses.len(), 1);
    match &responses[0] {
        Response::Error { id, code, reason } => {
            assert_eq!(id, "late");
            assert_eq!(*code, "deadline");
            assert!(reason.contains("deadline of 0 ms"), "{reason}");
        }
        other => panic!("expected deadline error, got {other:?}"),
    }
    assert_eq!(counters.deadlines, 1);
    assert_eq!(counters.errors, 1);
    assert_eq!(counters.completed, 0);
}

#[test]
fn checkpoint_and_scrub_knobs_leave_clean_histories_bitwise_identical() {
    for method in ["jacobi", "cg", "bicgstab"] {
        let spec = |ck: usize, sc: usize| {
            RunSpec::builder()
                .method_str(method)
                .grid(Grid3::new(6, 6, 8))
                .ranks(2)
                .checkpoint_every(ck)
                .scrub_every(sc)
                .build()
                .unwrap()
        };
        let off = Session::new().run(&spec(0, 0)).expect("knobs-off run");
        let on = Session::new().run(&spec(3, 2)).expect("knobs-on run");
        assert_eq!(
            history_digest(&on.history),
            history_digest(&off.history),
            "{method}: checkpoint/scrub must not perturb numerics"
        );
        assert_eq!(
            on.rel_residual.to_bits(),
            off.rel_residual.to_bits(),
            "{method}: final residual must be bitwise unchanged"
        );
        assert!(on.checkpoints >= 1, "{method}: cadence must capture");
        assert_eq!(on.rollbacks, 0, "{method}: no fault, no rollback");
        assert_eq!(on.corruptions, 0, "{method}: clean run is clean");
        assert_eq!(off.checkpoints, 0, "{method}: knobs off capture nothing");
    }
}

#[test]
fn silent_corruption_rolls_back_and_replays_bitwise_across_strategies() {
    let strategies = [
        ExecSpec::new(ExecStrategy::Seq, 1),
        ExecSpec::new(ExecStrategy::ForkJoin, 2),
        ExecSpec::new(ExecStrategy::TaskPool, 2),
    ];
    for transport in [TransportKind::Lockstep, TransportKind::Threaded] {
        for exec in &strategies {
            let base = |fault: Option<Fault>| {
                let mut b = RunSpec::builder()
                    .method_str("cg")
                    .grid(Grid3::new(6, 6, 8))
                    .ranks(2)
                    .transport(transport)
                    .exec(exec.clone())
                    .checkpoint_every(2)
                    .scrub_every(1);
                if let Some(f) = fault {
                    b = b.push_fault(f);
                }
                b.build().unwrap()
            };
            let tag = format!("{transport:?}/{:?}", exec.strategy);
            let clean = Session::new().run(&base(None)).expect("clean run");
            assert!(clean.iterations >= 7, "{tag}: grid too easy for the fault plan");
            assert_eq!(clean.rollbacks, 0, "{tag}: clean run never rolls back");

            // allreduce ordinal 13 is iteration 4's pAp fold (one init
            // fold, then three checked collectives per scrubbed CG
            // iteration): the duplicate-fold checksum trips at k=4, the
            // latest snapshot is completed=4 (cadence 2), and the
            // replayed tail must land bitwise on the clean run
            let rec = Session::new()
                .run(&base(Some(Fault {
                    kind: FaultKind::SilentAllreduce,
                    rank: 1,
                    at: 13,
                    delay_ms: 0,
                })))
                .unwrap_or_else(|e| panic!("{tag}: rollback must absorb the corruption: {e}"));
            assert_eq!(rec.rollbacks, 1, "{tag}: one rollback heals one fault");
            assert_eq!(rec.corruptions, 1, "{tag}: the checksum guard must fire");
            assert_eq!(rec.resumed_from, Some(4), "{tag}: resume from the latest snapshot");
            assert!(rec.checkpoints >= 2, "{tag}: cadence must keep capturing");
            assert_eq!(
                history_digest(&rec.history),
                history_digest(&clean.history),
                "{tag}: recovery must replay bitwise"
            );
            assert_eq!(
                rec.rel_residual.to_bits(),
                clean.rel_residual.to_bits(),
                "{tag}: final residual must be bitwise the clean one"
            );
        }
    }
}

#[test]
fn transport_abort_rolls_back_to_the_latest_checkpoint() {
    for transport in [TransportKind::Lockstep, TransportKind::Threaded] {
        let base = |fault: Option<Fault>| {
            let mut b = RunSpec::builder()
                .method_str("cg")
                .grid(Grid3::new(6, 6, 8))
                .ranks(2)
                .transport(transport)
                .checkpoint_every(2)
                .scrub_every(1);
            if let Some(f) = fault {
                b = b.push_fault(f);
            }
            b.build().unwrap()
        };
        let clean = Session::new().run(&base(None)).expect("clean run");
        // abort faults fire on *wait* ordinals, which don't map 1:1 to
        // iterations — scan a few mid-solve ordinals. Any abort landing
        // after the first snapshot (and before convergence) must heal,
        // and a healed run must be bitwise the clean one. Ordinals that
        // strike before the first snapshot surface as transport errors,
        // ordinals past convergence never fire; both are skipped.
        let mut proved = false;
        for at in [24, 33, 42, 51, 60] {
            let outcome = Session::new().run(&base(Some(Fault {
                kind: FaultKind::Abort,
                rank: 1,
                at,
                delay_ms: 0,
            })));
            let Ok(rec) = outcome else { continue };
            if rec.rollbacks == 0 {
                continue;
            }
            assert!(rec.resumed_from.is_some(), "{transport:?}@{at}");
            assert_eq!(
                history_digest(&rec.history),
                history_digest(&clean.history),
                "{transport:?}@{at}: recovery must replay bitwise"
            );
            assert_eq!(
                rec.rel_residual.to_bits(),
                clean.rel_residual.to_bits(),
                "{transport:?}@{at}: final residual must match"
            );
            proved = true;
        }
        assert!(proved, "{transport:?}: no scanned abort ordinal recovered");
    }
}

/// Counts `on_iteration` callbacks on rank 0 — each one is an executed
/// (not skipped) recording step, so the surplus over a clean run bounds
/// how much work a rollback re-executed.
struct RankZeroIterationCount(AtomicUsize);

impl Observer for RankZeroIterationCount {
    fn on_iteration(&self, rank: usize, _iteration: usize, _rel: f64) {
        if rank == 0 {
            self.0.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[test]
fn rollback_reexecutes_only_the_post_checkpoint_segment() {
    let base = |fault: Option<Fault>| {
        let mut b = RunSpec::builder()
            .method_str("jacobi")
            .grid(Grid3::new(6, 6, 8))
            .ranks(2)
            .checkpoint_every(3)
            .scrub_every(1);
        if let Some(f) = fault {
            b = b.push_fault(f);
        }
        b.build().unwrap()
    };
    let count_run = |spec: &RunSpec| {
        let obs = RankZeroIterationCount(AtomicUsize::new(0));
        let stats = Session::new()
            .run_observed(spec, &obs)
            .expect("solve completes");
        (stats, obs.0.into_inner())
    };
    let (clean, clean_calls) = count_run(&base(None));
    assert!(clean.iterations > 8, "jacobi must outlive the fault ordinal");
    assert_eq!(clean_calls, clean.iterations, "one callback per iteration");

    // Jacobi folds one checked allreduce per iteration, so ordinal 7 is
    // iteration 7's residual fold; snapshots land at completed 3 and 6
    let (rec, rec_calls) = count_run(&base(Some(Fault {
        kind: FaultKind::SilentAllreduce,
        rank: 0,
        at: 7,
        delay_ms: 0,
    })));
    assert_eq!(rec.resumed_from, Some(6), "resume from the latest snapshot");
    assert_eq!(rec.corruptions, 1);
    assert_eq!(rec.rollbacks, 1);
    assert_eq!(
        history_digest(&rec.history),
        history_digest(&clean.history),
        "recovery must replay bitwise"
    );
    // the retry resumed from completed=6 and the fault hit at 7: only
    // that sliver re-executes. The callback surplus over the clean run
    // is bounded by the replayed window — nowhere near the cold restart
    // (a full extra `clean.iterations`) this guards against.
    let dup = rec_calls - clean_calls;
    assert!(
        (1..=2).contains(&dup),
        "expected a 1-2 iteration replay window, got {dup} extra callbacks"
    );
}

#[test]
fn service_warm_resume_salvages_checkpoints_across_a_worker_panic() {
    let clean = RunSpec::builder()
        .method_str("cg")
        .grid(Grid3::new(6, 6, 8))
        .ranks(2)
        .checkpoint_every(1)
        .build()
        .unwrap();
    let reference = Session::new().run(&clean).expect("reference solve");
    let ref_digest = history_digest(&reference.history);

    let service = Service::start(ServiceConfig {
        workers: 1,
        total_threads: 2,
        queue_cap: 8,
        default_iter_budget: None,
        exec_cache_sets: 4,
        default_deadline_ms: None,
        max_retries: 8,
    });
    // the spec's panic re-fires at the same wait ordinal on every
    // attempt, but each warm resume starts deeper into the solve, so a
    // later attempt runs out of waits before the ordinal and completes.
    // Whether the first panicked attempt leaves a *rank-consistent*
    // snapshot to salvage depends on where the ordinal lands inside an
    // iteration, so scan a few — at least one must heal.
    let ats: [usize; 5] = [18, 25, 32, 39, 46];
    for (i, at) in ats.iter().enumerate() {
        let mut spec = clean.clone();
        spec.fault.faults.push(Fault {
            kind: FaultKind::Panic,
            rank: 0,
            at: *at,
            delay_ms: 0,
        });
        service.submit(
            SolveRequest {
                id: Some(format!("wr-{i}")),
                spec,
                iter_budget: None,
                deadline_ms: None,
            },
            None,
        );
    }
    let responses = service.drain();
    let counters = service.shutdown();
    assert_eq!(responses.len(), ats.len(), "one response per request");

    let mut recovered: u64 = 0;
    for resp in &responses {
        let Some(ok) = resp.as_ok() else { continue };
        if ok.rollbacks == 0 {
            // the ordinal outlived the solve: the fault never fired
            continue;
        }
        assert!(ok.resumed_from.is_some(), "{}", resp.id());
        assert_eq!(
            ok.history_digest, ref_digest,
            "{}: a warm resume must replay bitwise",
            resp.id()
        );
        assert_eq!(
            ok.rel_residual_bits,
            reference.rel_residual.to_bits(),
            "{}: final residual must match the uninterrupted run",
            resp.id()
        );
        recovered += 1;
    }
    assert!(recovered >= 1, "no scanned panic ordinal produced a warm resume");
    assert!(
        counters.rollbacks >= recovered,
        "rollback telemetry must cover every resume"
    );
    assert!(counters.panics >= recovered, "every resume began with a panic");
    assert!(counters.retried >= recovered, "every resume is a requeue");
}
