//! Acceptance contract of the failure taxonomy + deterministic fault
//! injection (ISSUE 9):
//!
//!  * injected transport faults surface as structured
//!    [`SolveError::TransportFailure`] values naming the originating
//!    rank and phase — never as a process abort — on both transports;
//!  * a threaded rank stalled past the configured `deadlock_timeout_ms`
//!    is diagnosed as a timeout, while the lockstep oracle (which
//!    serialises ranks and therefore cannot time out) completes the
//!    same plan;
//!  * corrupted allreduce payloads trip the solver guards into the
//!    structured taxonomy (`non-finite` / `solver-breakdown`), and the
//!    verdict is identical on every replay;
//!  * BiCGStab's deterministic breakdown restart turns an injected
//!    breakdown into a converged solve once `SolveOpts::restarts`
//!    grants budget;
//!  * faults that only perturb *timing* (delayed allreduce posts) leave
//!    convergence histories bitwise identical to the fault-free run;
//!  * a seeded chaos plan replays to the identical outcome, Ok or Err;
//!  * the solve service drains a chaos trace (≥25 % injected failures,
//!    including raw panics) with exactly one structured response per
//!    request, bitwise-identical results for the fault-free jobs, and
//!    telemetry that accounts for every panic, retry, and deadline.

use hlam::api::{RunSpec, Session, SolveError};
use hlam::mesh::Grid3;
use hlam::service::{history_digest, Response, Service, ServiceConfig, SolveRequest};
use hlam::simmpi::{Fault, FaultKind, FaultPlan, TransportKind};

/// A small 2-rank spec with one explicit fault installed.
fn faulty_spec(
    method: &str,
    transport: TransportKind,
    kind: FaultKind,
    rank: usize,
    at: usize,
    delay_ms: u64,
) -> RunSpec {
    RunSpec::builder()
        .method_str(method)
        .grid(Grid3::new(6, 6, 8))
        .ranks(2)
        .transport(transport)
        .push_fault(Fault {
            kind,
            rank,
            at,
            delay_ms,
        })
        .build()
        .expect("fault spec builds")
}

#[test]
fn injected_abort_surfaces_as_structured_transport_failure() {
    for transport in [TransportKind::Lockstep, TransportKind::Threaded] {
        let spec = faulty_spec("cg", transport, FaultKind::Abort, 1, 2, 0);
        let err = Session::new()
            .run(&spec)
            .expect_err("an aborted rank cannot produce a clean solve");
        match &err {
            SolveError::TransportFailure { rank, what, .. } => {
                // primary-failure selection reports the *originating*
                // abort, not the peer-echo failures it causes on rank 0
                assert_eq!(*rank, 1, "{transport:?}: wrong originating rank");
                assert_eq!(what, "injected abort", "{transport:?}");
            }
            other => panic!("{transport:?}: expected transport failure, got {other:?}"),
        }
    }
}

#[test]
fn threaded_stall_times_out_while_lockstep_completes() {
    let stalled = |transport| {
        let mut spec = faulty_spec("cg", transport, FaultKind::Stall, 0, 2, 150);
        // rank 1 blocks on rank 0's contribution; the 150 ms stall per
        // wait must overrun this window decisively
        spec.deadlock_timeout_ms = 40;
        spec
    };
    let err = Session::new()
        .run(&stalled(TransportKind::Threaded))
        .expect_err("a stalled threaded rank must be diagnosed, not waited out");
    assert!(
        matches!(err, SolveError::TransportFailure { .. }),
        "expected a transport timeout, got {err:?}"
    );
    // lockstep serialises ranks, so a stall is slow but never stuck:
    // the same plan (same timeout knob) completes and converges
    let stats = Session::new()
        .run(&stalled(TransportKind::Lockstep))
        .expect("lockstep survives a pure stall");
    assert!(stats.converged);
}

#[test]
fn corrupted_allreduce_fails_structurally_and_identically_on_replay() {
    let spec = faulty_spec(
        "cg",
        TransportKind::Lockstep,
        FaultKind::CorruptAllreduce,
        0,
        1,
        0,
    );
    let verdict = |spec: &RunSpec| {
        let err = Session::new()
            .run(spec)
            .expect_err("NaN lanes in an allreduce cannot converge");
        assert!(
            matches!(
                err,
                SolveError::NonFinite { .. }
                    | SolveError::Breakdown { .. }
                    | SolveError::Diverged { .. }
            ),
            "corruption must land in the solver taxonomy, got {err:?}"
        );
        err.to_string()
    };
    assert_eq!(verdict(&spec), verdict(&spec), "verdict must replay");
}

#[test]
fn bicgstab_restart_recovers_from_an_injected_breakdown() {
    let spec_at = |at: usize, restarts: usize| {
        let mut spec = faulty_spec(
            "bicgstab",
            TransportKind::Lockstep,
            FaultKind::CorruptAllreduce,
            0,
            at,
            0,
        );
        spec.grid = Grid3::new(8, 8, 16);
        spec.opts.restarts = restarts;
        spec
    };
    // scan the first few allreduce ordinals for one whose corruption
    // lands in a guarded Krylov denominator (ρ, r'·Ap, ω) — the NaN is
    // indistinguishable from a true breakdown to the guard
    let broken_at = (0..8).find(|&at| {
        matches!(
            Session::new().run(&spec_at(at, 0)),
            Err(SolveError::Breakdown { .. })
        )
    });
    let at = broken_at.expect("some early allreduce ordinal must hit a breakdown guard");
    // the same fault with restart budget: the reseed consumes the
    // poisoned direction and the solve completes cleanly
    let stats = Session::new()
        .run(&spec_at(at, 3))
        .expect("restart budget must absorb the injected breakdown");
    assert!(stats.converged, "restarted solve must converge");
    assert!(stats.restarts >= 1, "recovery must be via restart");
}

#[test]
fn delay_faults_leave_histories_bitwise_identical() {
    let base = |kind: Option<FaultKind>| {
        let mut b = RunSpec::builder()
            .method_str("cg")
            .grid(Grid3::new(6, 6, 8))
            .ranks(2)
            .transport(TransportKind::Threaded);
        if let Some(kind) = kind {
            b = b.push_fault(Fault {
                kind,
                rank: 1,
                at: 1,
                delay_ms: 30,
            });
        }
        b.build().unwrap()
    };
    let clean = Session::new().run(&base(None)).expect("clean run");
    for kind in [FaultKind::DelayAllreduce, FaultKind::Stall] {
        let slowed = Session::new()
            .run(&base(Some(kind)))
            .expect("timing faults do not fail a solve");
        assert_eq!(
            history_digest(&slowed.history),
            history_digest(&clean.history),
            "{kind:?} must not perturb numerics"
        );
        assert_eq!(
            slowed.rel_residual.to_bits(),
            clean.rel_residual.to_bits(),
            "{kind:?} must not perturb the final residual"
        );
    }
}

#[test]
fn seeded_chaos_plans_replay_identically_across_methods_and_transports() {
    let outcome = |spec: &RunSpec| match Session::new().run(spec) {
        Ok(stats) => format!(
            "ok:{}:{:016x}",
            stats.history.len(),
            history_digest(&stats.history)
        ),
        Err(e) => format!("err:{e}"),
    };
    for method in ["cg", "bicgstab", "multisplit"] {
        for transport in [TransportKind::Lockstep, TransportKind::Threaded] {
            for seed in 1..=3u64 {
                let spec = RunSpec::builder()
                    .method_str(method)
                    .grid(Grid3::new(6, 6, 8))
                    .ranks(2)
                    .transport(transport)
                    .fault(FaultPlan {
                        seed,
                        faults: Vec::new(),
                    })
                    .build()
                    .unwrap();
                let first = outcome(&spec);
                assert_eq!(
                    first,
                    outcome(&spec),
                    "{method}/{transport:?}: chaos seed {seed} must replay"
                );
                // the derived chaos plan never injects a raw panic, so
                // every outcome is structured: a clean solve (timing
                // faults) or a taxonomy error — never a process abort
                assert!(
                    first.starts_with("ok:") || first.starts_with("err:"),
                    "{first}"
                );
            }
        }
    }
}

#[test]
fn service_chaos_drain_answers_every_request_exactly_once() {
    const JOBS: usize = 16;
    let clean = RunSpec::builder()
        .method_str("cg")
        .grid(Grid3::new(6, 6, 8))
        .ranks(2)
        .build()
        .unwrap();
    let reference = Session::new().run(&clean).expect("reference solve");
    let ref_digest = history_digest(&reference.history);

    let with_fault = |kind: FaultKind, rank: usize| {
        let mut spec = clean.clone();
        spec.fault.faults.push(Fault {
            kind,
            rank,
            at: 2,
            delay_ms: 0,
        });
        spec
    };
    let service = Service::start(ServiceConfig {
        workers: 2,
        total_threads: 4,
        queue_cap: JOBS,
        default_iter_budget: None,
        exec_cache_sets: 4,
        default_deadline_ms: None,
        max_retries: 1,
    });
    // 75 % injected failures (≥ the 25 % the acceptance bar asks for):
    // raw panics, structured aborts, corrupted numerics, then clean
    for i in 0..JOBS {
        let spec = match i % 4 {
            0 => with_fault(FaultKind::Panic, 0),
            1 => with_fault(FaultKind::Abort, 1),
            2 => with_fault(FaultKind::CorruptAllreduce, 0),
            _ => clean.clone(),
        };
        service.submit(
            SolveRequest {
                id: Some(format!("c-{i}")),
                spec,
                iter_budget: None,
                deadline_ms: None,
            },
            None,
        );
    }
    let responses = service.drain();
    let counters = service.shutdown();

    assert_eq!(responses.len(), JOBS, "exactly one response per request");
    let mut ids: Vec<&str> = responses.iter().map(Response::id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), JOBS, "no duplicate responses");

    for i in 0..JOBS {
        let id = format!("c-{i}");
        let resp = responses.iter().find(|r| r.id() == id).unwrap();
        match i % 4 {
            0 => match resp {
                // a panicking job is retried once on a rebuilt session,
                // panics again (the fault is in the spec), and only the
                // final attempt answers
                Response::Error { code, reason, .. } => {
                    assert_eq!(*code, "internal-panic", "{id}");
                    assert!(reason.contains("attempt 2"), "{id}: {reason}");
                    assert!(reason.contains("injected panic"), "{id}: {reason}");
                }
                other => panic!("{id}: expected internal-panic, got {other:?}"),
            },
            1 => match resp {
                Response::Error { code, reason, .. } => {
                    assert_eq!(*code, "transport", "{id}");
                    assert!(reason.contains("injected abort"), "{id}: {reason}");
                }
                other => panic!("{id}: expected transport error, got {other:?}"),
            },
            2 => match resp {
                Response::Error { code, .. } => {
                    assert!(
                        ["non-finite", "solver-breakdown", "diverged"].contains(code),
                        "{id}: corrupted numerics must land in the taxonomy, got {code}"
                    );
                }
                other => panic!("{id}: expected solver error, got {other:?}"),
            },
            _ => {
                let ok = resp
                    .as_ok()
                    .unwrap_or_else(|| panic!("{id}: clean job failed: {resp:?}"));
                // chaos on neighbouring jobs must not leak into clean
                // results — bitwise identical to the single-shot run
                assert_eq!(ok.history_digest, ref_digest, "{id}");
                assert_eq!(ok.rel_residual_bits, reference.rel_residual.to_bits(), "{id}");
            }
        }
    }
    let quarter = (JOBS / 4) as u64;
    assert_eq!(counters.completed, quarter, "clean jobs");
    assert_eq!(counters.errors, 3 * quarter, "faulted jobs");
    assert_eq!(counters.retried, quarter, "each panic job retried once");
    assert_eq!(counters.panics, 2 * quarter, "both attempts panicked");
    assert_eq!(counters.deadlines, 0);
    assert_eq!(counters.accepted, JOBS as u64);
}

#[test]
fn expired_deadline_answers_with_the_deadline_code() {
    let service = Service::start(ServiceConfig {
        workers: 1,
        total_threads: 2,
        queue_cap: 4,
        default_iter_budget: None,
        exec_cache_sets: 4,
        default_deadline_ms: None,
        max_retries: 1,
    });
    let mut spec = RunSpec::default();
    spec.grid = Grid3::new(6, 6, 8);
    service.submit(
        SolveRequest {
            id: Some("late".to_string()),
            spec,
            iter_budget: None,
            // already expired on arrival: the memoised deadline observer
            // stops the solve at its first verdict and the job answers
            // with the deadline code instead of a partial ok
            deadline_ms: Some(0),
        },
        None,
    );
    let responses = service.drain();
    let counters = service.shutdown();
    assert_eq!(responses.len(), 1);
    match &responses[0] {
        Response::Error { id, code, reason } => {
            assert_eq!(id, "late");
            assert_eq!(*code, "deadline");
            assert!(reason.contains("deadline of 0 ms"), "{reason}");
        }
        other => panic!("expected deadline error, got {other:?}"),
    }
    assert_eq!(counters.deadlines, 1);
    assert_eq!(counters.errors, 1);
    assert_eq!(counters.completed, 0);
}
