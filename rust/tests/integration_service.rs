//! Acceptance contract of the concurrent solve service (ISSUE 8):
//!
//!  * replaying a mixed workload trace through the service at
//!    concurrency 1 and 4 yields, for every job, a convergence history
//!    **bitwise identical** to a fresh single-shot `Session::run` of
//!    the same spec — including jobs that hit a worker's batched
//!    assembly cache;
//!  * admission control is structured and deterministic: `queue-full`
//!    past the cap, `over-budget` for specs that could never lease,
//!    `backend-unsupported` for non-native specs, with exactly one
//!    terminal response per request;
//!  * cancellation removes queued jobs only, and the per-job iteration
//!    budget reproduces a single-shot `run_observed` with the same
//!    `IterationCap` — bit for bit.

use std::collections::BTreeMap;

use hlam::api::{BackendKind, RunSpec, Session};
use hlam::harness::workload_trace;
use hlam::mesh::Grid3;
use hlam::service::{
    history_digest, IterationCap, RejectCode, Response, Service, ServiceConfig, SolveRequest,
};

const TRACE_LEN: usize = 24;
const TRACE_SEED: u64 = 11;

fn submit(service: &Service, id: &str, spec: &RunSpec, iter_budget: Option<usize>) {
    service.submit(
        SolveRequest {
            id: Some(id.to_string()),
            spec: spec.clone(),
            iter_budget,
            deadline_ms: None,
        },
        None,
    );
}

/// A small fast-converging spec for the admission/cancel tests.
fn tiny_spec() -> RunSpec {
    let mut spec = RunSpec::default();
    spec.grid = Grid3::new(6, 6, 8);
    spec
}

#[test]
fn service_results_are_bitwise_identical_to_single_shot_runs() {
    let trace = workload_trace(TRACE_LEN, TRACE_SEED);
    // reference: each spec solved single-shot in a fresh session (no
    // cache, no concurrency, no budget)
    let reference: Vec<_> = trace
        .iter()
        .map(|spec| {
            let stats = Session::new().run(spec).expect("single-shot solve");
            let digest = history_digest(&stats.history);
            let bits = stats.rel_residual.to_bits();
            (digest, stats.history.len(), bits)
        })
        .collect();

    for workers in [1usize, 4] {
        let service = Service::start(ServiceConfig {
            workers,
            total_threads: 4,
            queue_cap: TRACE_LEN,
            default_iter_budget: None,
            exec_cache_sets: 4,
            default_deadline_ms: None,
            max_retries: 1,
        });
        for (i, spec) in trace.iter().enumerate() {
            submit(&service, &format!("t-{i}"), spec, None);
        }
        let responses = service.drain();
        let counters = service.shutdown();
        assert_eq!(responses.len(), TRACE_LEN, "one response per request");

        let by_id: BTreeMap<&str, &Response> = responses.iter().map(|r| (r.id(), r)).collect();
        let mut batched_and_checked = 0u64;
        for (i, (digest, len, bits)) in reference.iter().enumerate() {
            let ok = by_id[format!("t-{i}").as_str()]
                .as_ok()
                .unwrap_or_else(|| panic!("t-{i} must be ok at {workers} workers"));
            assert_eq!(
                (ok.history_digest, ok.history_len, ok.rel_residual_bits),
                (*digest, *len, *bits),
                "t-{i} ({}) at {workers} workers diverged from single-shot",
                ok.method
            );
            if ok.batch_hit {
                batched_and_checked += 1;
            }
        }
        assert_eq!(counters.completed, TRACE_LEN as u64);
        assert_eq!(counters.batch_hits, batched_and_checked);
        // every job after the first of its plan reuses that worker's
        // cached assembly, so the hit count is exact, not probabilistic
        let mut plans: Vec<String> = trace
            .iter()
            .map(|s| {
                format!(
                    "{}x{}x{}/p{}/r{}",
                    s.grid.nx,
                    s.grid.ny,
                    s.grid.nz,
                    s.stencil.width(),
                    s.ranks
                )
            })
            .collect();
        plans.sort();
        plans.dedup();
        assert_eq!(counters.distinct_plans, plans.len() as u64);
        assert_eq!(
            counters.batch_hits,
            (TRACE_LEN - plans.len()) as u64,
            "all but each plan's first job must be batch hits"
        );
        assert!(counters.peak_lanes <= counters.total_lanes, "budget held");
    }
}

#[test]
fn queue_cap_sheds_load_deterministically() {
    // paused scheduling: no worker drains the queue, so a cap of 2
    // admits exactly the first two submissions
    let service = Service::start_paused(ServiceConfig {
        workers: 1,
        total_threads: 4,
        queue_cap: 2,
        default_iter_budget: None,
        exec_cache_sets: 4,
        default_deadline_ms: None,
        max_retries: 1,
    });
    let spec = tiny_spec();
    for i in 0..5 {
        submit(&service, &format!("q-{i}"), &spec, None);
    }
    service.resume();
    let responses = service.drain();
    let counters = service.shutdown();
    assert_eq!(responses.len(), 5);
    let rejected: Vec<&str> = responses
        .iter()
        .filter(|r| {
            matches!(
                r,
                Response::Reject {
                    code: RejectCode::QueueFull,
                    ..
                }
            )
        })
        .map(Response::id)
        .collect();
    assert_eq!(rejected, ["q-2", "q-3", "q-4"], "exactly the overflow");
    assert_eq!(counters.accepted, 2);
    assert_eq!(counters.completed, 2);
    assert_eq!(counters.rejected, 3);
}

#[test]
fn impossible_specs_are_rejected_up_front() {
    let service = Service::start(ServiceConfig {
        workers: 1,
        total_threads: 2,
        queue_cap: 8,
        default_iter_budget: None,
        exec_cache_sets: 4,
        default_deadline_ms: None,
        max_retries: 1,
    });
    // 2 ranks x 2 threads = 4 lanes can never lease from a 2-lane budget
    let mut over = tiny_spec();
    over.ranks = 2;
    over.exec.threads = 2;
    submit(&service, "over", &over, None);
    // xla validates (lockstep + ell) but the service is native-only
    let mut xla = tiny_spec();
    xla.backend = BackendKind::Xla;
    submit(&service, "xla", &xla, None);
    // an invalid spec never reaches the queue
    let mut bad = tiny_spec();
    bad.ranks = 0;
    submit(&service, "bad", &bad, None);
    let responses = service.drain();
    let counters = service.shutdown();
    assert_eq!(responses.len(), 3);
    let code_of = |id: &str| match responses.iter().find(|r| r.id() == id) {
        Some(Response::Reject { code, .. }) => *code,
        other => panic!("{id}: expected reject, got {other:?}"),
    };
    assert_eq!(code_of("over"), RejectCode::OverBudget);
    assert_eq!(code_of("xla"), RejectCode::BackendUnsupported);
    assert_eq!(code_of("bad"), RejectCode::SpecInvalid);
    assert_eq!(counters.accepted, 0);
    assert_eq!(counters.rejected, 3);
}

#[test]
fn cancel_removes_queued_jobs_only() {
    let service = Service::start_paused(ServiceConfig {
        workers: 1,
        total_threads: 4,
        queue_cap: 8,
        default_iter_budget: None,
        exec_cache_sets: 4,
        default_deadline_ms: None,
        max_retries: 1,
    });
    let spec = tiny_spec();
    submit(&service, "keep", &spec, None);
    submit(&service, "drop", &spec, None);
    service.cancel("drop", None);
    service.cancel("ghost", None);
    service.resume();
    let responses = service.drain();
    let counters = service.shutdown();
    assert_eq!(
        responses.len(),
        3,
        "keep's solve, drop's cancel, ghost's reject"
    );
    let status_of = |id: &str| {
        responses
            .iter()
            .find(|r| r.id() == id)
            .map(Response::status)
            .unwrap_or_else(|| panic!("no response for {id}"))
    };
    assert_eq!(status_of("keep"), "ok");
    assert_eq!(status_of("drop"), "cancelled");
    match responses.iter().find(|r| r.id() == "ghost") {
        Some(Response::Reject { code, .. }) => assert_eq!(*code, RejectCode::NotPending),
        other => panic!("ghost: expected not-pending reject, got {other:?}"),
    }
    assert_eq!(counters.cancelled, 1);
    assert_eq!(counters.completed, 1);
}

#[test]
fn iteration_budget_matches_a_single_shot_observed_run() {
    let mut spec = RunSpec::default();
    spec.grid = Grid3::new(8, 8, 16);
    let cap = 3usize;
    let reference = Session::new()
        .run_observed(&spec, &IterationCap(cap))
        .expect("single-shot capped run");
    assert_eq!(reference.history.len(), cap, "the cap must bind");
    assert!(!reference.converged);

    let service = Service::start(ServiceConfig {
        workers: 2,
        total_threads: 4,
        queue_cap: 8,
        default_iter_budget: None,
        exec_cache_sets: 4,
        default_deadline_ms: None,
        max_retries: 1,
    });
    submit(&service, "capped", &spec, Some(cap));
    // the same spec without a budget must run past the cap
    submit(&service, "free", &spec, None);
    let responses = service.drain();
    drop(service);
    let capped = responses
        .iter()
        .find(|r| r.id() == "capped")
        .and_then(Response::as_ok)
        .expect("capped job ok");
    assert!(capped.early_stopped);
    assert_eq!(capped.history_len, cap);
    assert_eq!(capped.history_digest, history_digest(&reference.history));
    assert_eq!(capped.rel_residual_bits, reference.rel_residual.to_bits());
    let free = responses
        .iter()
        .find(|r| r.id() == "free")
        .and_then(Response::as_ok)
        .expect("free job ok");
    assert!(!free.early_stopped);
    assert!(free.history_len > cap);
}
