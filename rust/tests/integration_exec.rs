//! Executor equivalence — the acceptance contract of the exec refactor:
//! for each kernel and each method, `seq`, `fork-join` and `task` agree
//! across 1/2/4 threads. Vector kernels must agree *bitwise* (same chunk
//! decomposition, same scalar kernel per chunk); reductions must agree to
//! 1e-12 (they are in fact bitwise too, since the fold order is fixed,
//! but the looser bound is the documented guarantee).
//!
//! Chunk granularity is forced small (`with_chunk_rows`) so even the toy
//! test systems split into many chunks and the parallel paths genuinely
//! execute — with the default granularity these grids would collapse to
//! one chunk and the test would prove nothing.

use hlam::exec::{fold, split_rows, ExecSpec, ExecStrategy, Executor, Reduction};
use hlam::kernels;
use hlam::mesh::Grid3;
use hlam::simmpi::TransportKind;
use hlam::solvers::{
    completion_order, Method, Native, Ops, PrecondKind, Problem, SolveOpts, SolveStats,
};
use hlam::sparse::{KernelKind, LocalSystem, StencilKind};
use hlam::util::proptest::forall;
use hlam::util::Rng;

/// Every (strategy, threads) combination under test. The first entry is
/// the reference.
fn executors(chunk_rows: usize) -> Vec<(Executor, String)> {
    let mut out = Vec::new();
    for (strategy, threads) in [
        (ExecStrategy::Seq, 1),
        (ExecStrategy::ForkJoin, 1),
        (ExecStrategy::ForkJoin, 2),
        (ExecStrategy::ForkJoin, 4),
        (ExecStrategy::TaskPool, 1),
        (ExecStrategy::TaskPool, 2),
        (ExecStrategy::TaskPool, 4),
    ] {
        out.push((
            Executor::new(strategy, threads).with_chunk_rows(chunk_rows),
            format!("{}x{threads}", strategy.name()),
        ));
    }
    out
}

fn ops<'a>(exec: &'a Executor, opts: &'a SolveOpts, backend: &'a mut Native) -> Ops<'a> {
    Ops::new(exec, opts, backend)
}

// ---------------------------------------------------------------------
// kernel-level equivalence
// ---------------------------------------------------------------------

#[test]
fn kernel_dot_equivalent_across_executors() {
    forall(
        1711,
        40,
        |r, s| {
            let n = 64 + r.below(400 * s.0.max(1));
            let x: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            let ntasks = [0usize, 5, 16][r.below(3)];
            (x, y, ntasks, r.next_u64())
        },
        |(x, y, ntasks, seed)| {
            let n = x.len();
            let opts = SolveOpts {
                ntasks: *ntasks,
                task_order_seed: *seed,
                ..SolveOpts::default()
            };
            let mut reference = None;
            for (exec, name) in executors(32) {
                let mut backend = Native;
                let mut o = ops(&exec, &opts, &mut backend);
                let plain = o.dot(x, y, n);
                let ordered = o.dot_ordered(x, y, n, 3);
                match &reference {
                    None => reference = Some((plain, ordered)),
                    Some((p, q)) => {
                        if (plain - p).abs() > 1e-12 * (1.0 + p.abs()) {
                            eprintln!("dot mismatch under {name}");
                            return false;
                        }
                        if (ordered - q).abs() > 1e-12 * (1.0 + q.abs()) {
                            eprintln!("ordered dot mismatch under {name}");
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

#[test]
fn kernel_axpby_bitwise_across_executors() {
    forall(
        2711,
        40,
        |r, s| {
            let n = 64 + r.below(300 * s.0.max(1));
            let x: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            (x, y, r.normal(), r.normal())
        },
        |(x, y0, a, b)| {
            let n = x.len();
            let opts = SolveOpts::default();
            let mut reference: Option<Vec<f64>> = None;
            for (exec, name) in executors(32) {
                let mut backend = Native;
                let mut o = ops(&exec, &opts, &mut backend);
                let mut y = y0.clone();
                o.axpby(*a, x, *b, &mut y, n);
                match &reference {
                    None => reference = Some(y),
                    Some(want) => {
                        if &y != want {
                            eprintln!("axpby mismatch under {name}");
                            return false;
                        }
                    }
                }
            }
            true
        },
    );
}

#[test]
fn kernel_spmv_and_jacobi_bitwise_across_executors() {
    let sys = LocalSystem::build(Grid3::new(8, 8, 14), StencilKind::P7, 0, 1);
    let n = sys.n();
    let mut rng = Rng::new(77);
    let mut x = sys.new_ext();
    for v in x.iter_mut().take(n) {
        *v = rng.normal();
    }
    let opts = SolveOpts::default();

    let mut want_y = vec![0.0; n];
    kernels::spmv_ell(&sys.a, &x, &mut want_y, 0, n);
    let mut want_xn = vec![0.0; n];
    let want_res = kernels::jacobi_sweep(&sys.a, &sys.b, &x, &mut want_xn, 0, n);

    for (exec, name) in executors(64) {
        let mut backend = Native;
        let mut o = ops(&exec, &opts, &mut backend);
        let mut y = vec![0.0; n];
        o.spmv(&sys.a, &x, &mut y);
        assert_eq!(y, want_y, "spmv mismatch under {name}");

        let mut xn = vec![0.0; n];
        let res = o.jacobi_step_ordered(&sys.a, &sys.b, &x, &mut xn, 0);
        assert_eq!(xn, want_xn, "jacobi iterate mismatch under {name}");
        assert!(
            (res - want_res).abs() <= 1e-12 * (1.0 + want_res.abs()),
            "jacobi residual mismatch under {name}: {res} vs {want_res}"
        );
    }
}

#[test]
fn kernel_spmv_dot_fusion_equivalent_across_executors() {
    let sys = LocalSystem::build(Grid3::new(8, 8, 12), StencilKind::P27, 0, 1);
    let n = sys.n();
    let mut rng = Rng::new(13);
    let mut x = sys.new_ext();
    for v in x.iter_mut().take(n) {
        *v = rng.normal();
    }
    let p: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    for ntasks in [0usize, 12] {
        let opts = SolveOpts {
            ntasks,
            task_order_seed: 5,
            ..SolveOpts::default()
        };
        let mut reference: Option<(Vec<f64>, f64)> = None;
        for (exec, name) in executors(48) {
            let mut backend = Native;
            let mut o = ops(&exec, &opts, &mut backend);
            let mut y = vec![0.0; n];
            let d = o.spmv_dot_ordered(&sys.a, &x, &mut y, &p, 4);
            match &reference {
                None => reference = Some((y, d)),
                Some((wy, wd)) => {
                    assert_eq!(&y, wy, "fused spmv vector mismatch under {name}");
                    assert!(
                        (d - wd).abs() <= 1e-12 * (1.0 + wd.abs()),
                        "fused dot mismatch under {name} (ntasks={ntasks})"
                    );
                }
            }
        }
    }
}

#[test]
fn kernel_gs_colour_blocked_bitwise_across_executors() {
    let sys = LocalSystem::build(Grid3::new(6, 6, 12), StencilKind::P7, 0, 1);
    let n = sys.n();
    let mut rng = Rng::new(31);
    let mut x0 = sys.new_ext();
    for v in x0.iter_mut().take(n) {
        *v = rng.normal();
    }
    let opts = SolveOpts {
        ntasks: 9,
        task_order_seed: 17,
        ..SolveOpts::default()
    };
    let mut reference: Option<(Vec<f64>, f64)> = None;
    for (exec, name) in executors(32) {
        let mut backend = Native;
        let mut o = ops(&exec, &opts, &mut backend);
        let mut x = x0.clone();
        let snapshot = x.clone();
        let res = o.gs_colour_blocked_ordered(
            &sys.a,
            &sys.b,
            &sys.red_mask,
            true,
            &mut x,
            &snapshot,
            2,
        );
        match &reference {
            None => reference = Some((x, res)),
            Some((wx, wres)) => {
                assert_eq!(&x, wx, "gs blocked iterate mismatch under {name}");
                assert!(
                    (res - wres).abs() <= 1e-12 * (1.0 + wres.abs()),
                    "gs blocked residual mismatch under {name}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// method-level equivalence: identical convergence histories
// ---------------------------------------------------------------------

const ALL_METHODS: [&str; 8] = [
    "jacobi",
    "gs",
    "gs-rb",
    "gs-relaxed",
    "cg",
    "cg-nb",
    "bicgstab",
    "bicgstab-b1",
];

fn run_with(method: &str, opts: &SolveOpts, exec: &Executor) -> SolveStats {
    let mut pb = Problem::build(Grid3::new(6, 6, 12), StencilKind::P7, 2);
    pb.solve_with(Method::parse(method).unwrap(), opts, &mut Native, exec)
}

fn assert_identical(a: &SolveStats, b: &SolveStats, ctx: &str) {
    assert_eq!(a.iterations, b.iterations, "{ctx}: iteration count");
    assert_eq!(a.converged, b.converged, "{ctx}: convergence flag");
    assert_eq!(a.restarts, b.restarts, "{ctx}: restart count");
    assert_eq!(
        a.rel_residual.to_bits(),
        b.rel_residual.to_bits(),
        "{ctx}: final residual"
    );
    assert_eq!(a.x_error.to_bits(), b.x_error.to_bits(), "{ctx}: x error");
    assert_eq!(a.history.len(), b.history.len(), "{ctx}: history length");
    for (i, (ha, hb)) in a.history.iter().zip(&b.history).enumerate() {
        assert_eq!(
            ha.to_bits(),
            hb.to_bits(),
            "{ctx}: history[{i}] {ha} vs {hb}"
        );
    }
}

#[test]
fn all_methods_identical_histories_across_executors() {
    for method in ALL_METHODS {
        let mut opts = SolveOpts::default();
        if method.starts_with("gs-") {
            opts.ntasks = 6;
            opts.task_order_seed = 3;
        }
        let reference = run_with(method, &opts, &Executor::seq().with_chunk_rows(24));
        assert!(reference.converged, "{method}: reference did not converge");
        for (exec, name) in executors(24) {
            let got = run_with(method, &opts, &exec);
            assert_identical(&reference, &got, &format!("{method} under {name}"));
        }
    }
}

#[test]
fn all_methods_identical_histories_with_task_order_seeds() {
    // §3.3 seeded task-order runs must also be executor-independent: the
    // shuffle is part of the *plan* (fold order), not of the schedule.
    for method in ALL_METHODS {
        let mut opts = SolveOpts::default();
        opts.ntasks = 8;
        opts.task_order_seed = 42;
        let reference = run_with(method, &opts, &Executor::seq().with_chunk_rows(24));
        for (exec, name) in executors(24) {
            let got = run_with(method, &opts, &exec);
            assert_identical(
                &reference,
                &got,
                &format!("{method} (seeded) under {name}"),
            );
        }
    }
}

#[test]
fn default_executor_unchanged_from_plain_solve() {
    // Problem::solve (no executor argument) must behave exactly like an
    // explicit sequential executor — the API refactor is behaviourally
    // invisible to existing callers.
    for method in ["cg", "bicgstab-b1", "jacobi"] {
        let opts = SolveOpts::default();
        let mut p1 = Problem::build(Grid3::new(6, 6, 12), StencilKind::P7, 2);
        let s1 = p1.solve(Method::parse(method).unwrap(), &opts, &mut Native);
        let s2 = run_with(method, &opts, &Executor::seq());
        // run_with uses the same grid/ranks; chunk_rows default in both
        assert_identical(&s1, &s2, method);
    }
}

// ---------------------------------------------------------------------
// transport equivalence: lockstep oracle vs real concurrent ranks
// ---------------------------------------------------------------------

/// The acceptance contract of the transport refactor: for every method
/// variant, every rank count and every executor strategy, the threaded
/// transport (real concurrent OS threads per rank) produces convergence
/// histories bitwise identical to the lockstep oracle — and to the
/// legacy `solve_with` shared-backend path.
#[test]
fn lockstep_vs_threaded_bitwise_all_methods_ranks_execs() {
    let grid = Grid3::new(6, 6, 12);
    for method in ALL_METHODS {
        let mut opts = SolveOpts::default();
        if method.starts_with("gs-") {
            opts.ntasks = 6;
            opts.task_order_seed = 3;
        }
        for ranks in [1usize, 2, 4] {
            // reference: the lockstep shared-backend oracle path
            let mut pref = Problem::build(grid, StencilKind::P7, ranks);
            let reference = pref.solve_with(
                Method::parse(method).unwrap(),
                &opts,
                &mut Native,
                &Executor::seq().with_chunk_rows(24),
            );
            assert!(
                reference.converged,
                "{method} x{ranks}: reference did not converge"
            );
            assert_eq!(
                pref.stats.max_concurrent_ranks, 1,
                "{method} x{ranks}: lockstep oracle must serialise"
            );
            for strategy in [ExecStrategy::Seq, ExecStrategy::ForkJoin, ExecStrategy::TaskPool] {
                for kind in [TransportKind::Lockstep, TransportKind::Threaded] {
                    let spec = ExecSpec::new(strategy, 2).with_chunk_rows(24);
                    let mut pb = Problem::build(grid, StencilKind::P7, ranks);
                    let got =
                        pb.solve_hybrid(Method::parse(method).unwrap(), &opts, &spec, kind);
                    let ctx = format!(
                        "{method} x{ranks} ranks, {} exec, {} transport",
                        strategy.name(),
                        kind.name()
                    );
                    assert_identical(&reference, &got, &ctx);
                    // concurrency accounting (the "really concurrent"
                    // acceptance criterion): lockstep's executing gauge
                    // is pinned at 1 (serialisation invariant); threaded
                    // concurrency is asserted via thread-id accounting —
                    // N distinct OS threads, all alive concurrently
                    // behind the startup barrier. The executing-overlap
                    // gauge is scheduler-dependent, so only >= 1 is
                    // asserted here.
                    match kind {
                        TransportKind::Lockstep => {
                            assert_eq!(pb.stats.max_concurrent_ranks, 1, "{ctx}");
                        }
                        TransportKind::Threaded => {
                            assert_eq!(pb.stats.rank_threads, ranks, "{ctx}");
                            assert!(pb.stats.max_concurrent_ranks >= 1, "{ctx}");
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// overlap equivalence: start → interior → finish → boundary schedule
// ---------------------------------------------------------------------

/// The acceptance contract of the halo-overlap optimisation: for every
/// method variant × rank count × executor strategy × transport, running
/// with `overlap: on` (halo exchange split into start/finish with the
/// halo-independent interior chunks computed while the messages are in
/// flight) produces convergence histories bitwise identical to
/// `overlap: off`. The chunk plans, scalar kernels, per-slot partial
/// positions and fold orders are unchanged — only the execution order
/// of independent rows moves, which floating point cannot observe.
#[test]
fn overlap_on_vs_off_bitwise_all_methods_ranks_execs_transports() {
    let grid = Grid3::new(6, 6, 12);
    for method in ALL_METHODS {
        let mut opts = SolveOpts::default();
        if method.starts_with("gs-") {
            opts.ntasks = 6;
            opts.task_order_seed = 3;
        }
        for ranks in [1usize, 2, 4] {
            for strategy in [ExecStrategy::Seq, ExecStrategy::ForkJoin, ExecStrategy::TaskPool] {
                for kind in [TransportKind::Lockstep, TransportKind::Threaded] {
                    let spec_off = ExecSpec::new(strategy, 2).with_chunk_rows(24);
                    let spec_on = spec_off.clone().with_overlap(true);
                    let m = Method::parse(method).unwrap();
                    let mut poff = Problem::build(grid, StencilKind::P7, ranks);
                    let off = poff.solve_hybrid(m, &opts, &spec_off, kind);
                    let mut pon = Problem::build(grid, StencilKind::P7, ranks);
                    let on = pon.solve_hybrid(m, &opts, &spec_on, kind);
                    let ctx = format!(
                        "{method} x{ranks} ranks, {} exec, {} transport",
                        strategy.name(),
                        kind.name()
                    );
                    assert!(off.converged, "{ctx}: did not converge");
                    assert_identical(&off, &on, &ctx);
                    // effectiveness accounting: the overlapped run did
                    // real interior work while messages were in flight —
                    // except for the inherently sequential GS variants,
                    // which keep the synchronous exchange by design
                    assert_eq!(poff.stats.overlapped_rows, 0, "{ctx}: off overlapped");
                    if ranks > 1 && method != "gs" && method != "gs-relaxed" {
                        assert!(
                            pon.stats.overlapped_rows > 0,
                            "{ctx}: no interior rows overlapped"
                        );
                    }
                    if ranks == 1 {
                        assert_eq!(pon.stats.overlapped_rows, 0, "{ctx}: no neighbours");
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// kernel-backend equivalence: csr / ell / sell / stencil
// ---------------------------------------------------------------------

/// The acceptance contract of the kernel-backend tier (DESIGN.md §9):
/// for every method variant × rank count × executor strategy × overlap
/// setting, switching the operator layout (`RunSpec::kernel`) between
/// csr, ell, sell and stencil produces bitwise-identical convergence
/// histories. All four layouts visit each row's structural entries in
/// the same slot order with the same scalar arithmetic, so the layout
/// is invisible to floating point — this sweep is what pins that.
#[test]
fn kernel_backends_bitwise_all_methods_ranks_execs_overlap() {
    let grid = Grid3::new(6, 6, 12);
    for method in ALL_METHODS {
        let mut opts = SolveOpts::default();
        if method.starts_with("gs-") {
            opts.ntasks = 6;
            opts.task_order_seed = 3;
        }
        for ranks in [1usize, 2, 4] {
            for strategy in [ExecStrategy::Seq, ExecStrategy::ForkJoin, ExecStrategy::TaskPool] {
                for overlap in [false, true] {
                    let spec = ExecSpec::new(strategy, 2)
                        .with_chunk_rows(24)
                        .with_overlap(overlap);
                    let m = Method::parse(method).unwrap();
                    let mut reference: Option<SolveStats> = None;
                    for kernel in KernelKind::ALL {
                        let mut pb = Problem::build(grid, StencilKind::P7, ranks);
                        pb.set_kernel(kernel);
                        let got = pb.solve_hybrid(m, &opts, &spec, TransportKind::Lockstep);
                        let ctx = format!(
                            "{method} x{ranks} ranks, {} exec, overlap={overlap}, kernel={}",
                            strategy.name(),
                            kernel.name()
                        );
                        match &reference {
                            None => {
                                assert!(got.converged, "{ctx}: did not converge");
                                reference = Some(got);
                            }
                            Some(want) => assert_identical(want, &got, &ctx),
                        }
                    }
                }
            }
        }
    }
}

/// The same contract across the threaded transport (really concurrent
/// rank threads): a compact spot-check — the full transport sweep is
/// covered kernel-independently above and in the lockstep-vs-threaded
/// test, and the layout cannot interact with message scheduling.
#[test]
fn kernel_backends_bitwise_under_threaded_transport() {
    let grid = Grid3::new(6, 6, 12);
    for method in ["cg-nb", "gs-rb", "bicgstab", "jacobi"] {
        let mut opts = SolveOpts::default();
        if method.starts_with("gs-") {
            opts.ntasks = 6;
            opts.task_order_seed = 3;
        }
        let m = Method::parse(method).unwrap();
        let spec = ExecSpec::new(ExecStrategy::TaskPool, 2)
            .with_chunk_rows(24)
            .with_overlap(true);
        let mut reference: Option<SolveStats> = None;
        for kernel in KernelKind::ALL {
            let mut pb = Problem::build(grid, StencilKind::P7, 2);
            pb.set_kernel(kernel);
            let got = pb.solve_hybrid(m, &opts, &spec, TransportKind::Threaded);
            let ctx = format!("{method} threaded, kernel={}", kernel.name());
            match &reference {
                None => {
                    assert!(got.converged, "{ctx}: did not converge");
                    reference = Some(got);
                }
                Some(want) => assert_identical(want, &got, &ctx),
            }
        }
    }
}

// ---------------------------------------------------------------------
// red-black GS per-colour fold regrouping (pinned)
// ---------------------------------------------------------------------

/// Ulp distance between two same-sign finite floats.
fn ulps_apart(a: f64, b: f64) -> u64 {
    assert!(a.is_finite() && b.is_finite() && (a >= 0.0) == (b >= 0.0));
    (a.to_bits() as i64).abs_diff(b.to_bits() as i64)
}

/// Pin the red-black Gauss-Seidel fold regrouping documented in
/// `solvers/driver.rs`: the refactored sweep folds each colour's
/// residual partials separately and sums the two colour totals, where
/// the pre-refactor loop chained one accumulator across both colours.
/// The two-colour-total must stay within the last couple of ulps of the
/// chained reference (one reassociation; 2 ulps bounds it, typically it
/// is 0-1) and must be bitwise strategy-independent — so the documented
/// last-ulp quirk can't silently drift into a real numerical change.
#[test]
fn red_black_colour_fold_regrouping_pinned() {
    let sys = LocalSystem::build(Grid3::new(6, 6, 12), StencilKind::P7, 0, 1);
    let n = sys.n();
    let ntasks = 2; // one reassociation between the two fold groupings
    let seed = 17;
    let key = 3;
    let opts = SolveOpts {
        ntasks,
        task_order_seed: seed,
        ..SolveOpts::default()
    };
    let mut rng = Rng::new(41);
    let mut x0 = sys.new_ext();
    for v in x0.iter_mut().take(n) {
        *v = rng.normal();
    }

    // reference per-block partials: every block sweeps against the same
    // pre-colour snapshot (the blocked-task semantics), index order
    let blocks = split_rows(n, ntasks);
    let order = completion_order(blocks.len(), seed, key);
    let mut xr = x0.clone();
    let snap_red = xr.clone();
    let red: Vec<f64> = blocks
        .iter()
        .map(|&(r0, r1)| {
            kernels::gs_colour_sweep_blocked(
                &sys.a, &sys.b, &sys.red_mask, true, &mut xr, &snap_red, r0, r1,
            )
        })
        .collect();
    let snap_black = xr.clone();
    let black: Vec<f64> = blocks
        .iter()
        .map(|&(r0, r1)| {
            kernels::gs_colour_sweep_blocked(
                &sys.a, &sys.b, &sys.red_mask, false, &mut xr, &snap_black, r0, r1,
            )
        })
        .collect();

    // new grouping: per-colour ordered folds, summed (what Ops does)
    let per_colour = fold(&red, &Reduction::Ordered(order.clone()))
        + fold(&black, &Reduction::Ordered(order.clone()));
    // old grouping: one accumulator chained across both colours
    let mut chained = 0.0;
    for &bi in &order {
        chained += red[bi];
    }
    for &bi in &order {
        chained += black[bi];
    }
    assert!(
        ulps_apart(per_colour, chained) <= 2,
        "regrouping drifted: per-colour {per_colour:.17e} vs chained {chained:.17e}"
    );

    // and the per-colour total is exactly what every executor produces
    for (exec, name) in executors(32) {
        let mut backend = Native;
        let mut o = ops(&exec, &opts, &mut backend);
        let mut x = x0.clone();
        let snap = x.clone();
        let got_red =
            o.gs_colour_blocked_ordered(&sys.a, &sys.b, &sys.red_mask, true, &mut x, &snap, key);
        let snap2 = x.clone();
        let got_black = o.gs_colour_blocked_ordered(
            &sys.a,
            &sys.b,
            &sys.red_mask,
            false,
            &mut x,
            &snap2,
            key,
        );
        let total = got_red + got_black;
        assert_eq!(
            total.to_bits(),
            per_colour.to_bits(),
            "fold not strategy-independent under {name}"
        );
        assert_eq!(x, xr, "iterate mismatch under {name}");
    }
}

// ---------------------------------------------------------------------
// preconditioner tier: bitwise determinism across every execution
// dimension, and precond:none ≡ the untouched legacy loops
// ---------------------------------------------------------------------

/// The (method, preconditioner, inner strength) cells of the
/// preconditioner sweep. Chebyshev gets a degree > 1 so its recurrence
/// actually recurs; multisplit exercises the outer/inner split.
const PRECOND_CASES: [(&str, PrecondKind, usize); 7] = [
    ("cg", PrecondKind::Jacobi, 2),
    ("cg", PrecondKind::BlockJacobi, 2),
    ("cg", PrecondKind::Chebyshev, 3),
    ("bicgstab", PrecondKind::Jacobi, 2),
    ("bicgstab", PrecondKind::BlockJacobi, 2),
    ("bicgstab", PrecondKind::Chebyshev, 3),
    ("multisplit", PrecondKind::BlockJacobi, 3),
];

/// The acceptance contract of the preconditioner tier (DESIGN.md §10):
/// every preconditioned method produces convergence histories bitwise
/// identical across executor strategies × transports × overlap modes at
/// each rank count. The M⁻¹ applies run through the same chunk-plan/Ops
/// machinery as the solver kernels, so the determinism argument of the
/// earlier tiers extends by construction — this sweep pins it.
#[test]
fn preconditioned_bitwise_across_ranks_execs_transports_overlap() {
    let grid = Grid3::new(6, 6, 12);
    for (method, precond, inner) in PRECOND_CASES {
        let opts = SolveOpts {
            precond,
            inner_iters: inner,
            ..SolveOpts::default()
        };
        let m = Method::parse(method).unwrap();
        for ranks in [1usize, 2, 4] {
            // rank-local preconditioning means histories legitimately
            // depend on the rank count; the reference is per-ranks
            let mut reference: Option<SolveStats> = None;
            for strategy in [ExecStrategy::Seq, ExecStrategy::ForkJoin, ExecStrategy::TaskPool] {
                for kind in [TransportKind::Lockstep, TransportKind::Threaded] {
                    for overlap in [false, true] {
                        let spec = ExecSpec::new(strategy, 2)
                            .with_chunk_rows(24)
                            .with_overlap(overlap);
                        let mut pb = Problem::build(grid, StencilKind::P7, ranks);
                        let got = pb.solve_hybrid(m, &opts, &spec, kind);
                        let ctx = format!(
                            "{method}/{} x{ranks} ranks, {} exec, {} transport, overlap={overlap}",
                            precond.name(),
                            strategy.name(),
                            kind.name()
                        );
                        match &reference {
                            None => {
                                assert!(got.converged, "{ctx}: did not converge");
                                reference = Some(got);
                            }
                            Some(want) => assert_identical(want, &got, &ctx),
                        }
                    }
                }
            }
        }
    }
}

/// Preconditioned histories are also layout-independent: a compact
/// kernel-backend spot-check (the full kernel sweep runs above for the
/// unpreconditioned methods; M⁻¹ uses the same kernel-dispatched ops).
#[test]
fn preconditioned_kernel_backends_bitwise() {
    let grid = Grid3::new(6, 6, 12);
    for (method, precond, inner) in [
        ("cg", PrecondKind::Chebyshev, 3),
        ("bicgstab", PrecondKind::BlockJacobi, 2),
        ("multisplit", PrecondKind::Jacobi, 3),
    ] {
        let opts = SolveOpts {
            precond,
            inner_iters: inner,
            ..SolveOpts::default()
        };
        let m = Method::parse(method).unwrap();
        let spec = ExecSpec::new(ExecStrategy::TaskPool, 2)
            .with_chunk_rows(24)
            .with_overlap(true);
        let mut reference: Option<SolveStats> = None;
        for kernel in KernelKind::ALL {
            let mut pb = Problem::build(grid, StencilKind::P7, 2);
            pb.set_kernel(kernel);
            let got = pb.solve_hybrid(m, &opts, &spec, TransportKind::Threaded);
            let ctx = format!("{method}/{} kernel={}", precond.name(), kernel.name());
            match &reference {
                None => {
                    assert!(got.converged, "{ctx}: did not converge");
                    reference = Some(got);
                }
                Some(want) => assert_identical(want, &got, &ctx),
            }
        }
    }
}

/// `precond: none` must route through the byte-untouched legacy loops:
/// explicit none (with a non-default inner_iters, which is inert
/// without a preconditioner) is bitwise identical to the default
/// options — a guard against `none` ever being rewritten as "identity
/// preconditioner through the PCG loop", which would reassociate dots.
#[test]
fn precond_none_identical_to_legacy_path() {
    for method in ["cg", "cg-nb", "bicgstab", "bicgstab-b1"] {
        let legacy = run_with(
            method,
            &SolveOpts::default(),
            &Executor::seq().with_chunk_rows(24),
        );
        let explicit = SolveOpts {
            precond: PrecondKind::None,
            inner_iters: 5,
            ..SolveOpts::default()
        };
        let got = run_with(method, &explicit, &Executor::seq().with_chunk_rows(24));
        assert_identical(&legacy, &got, &format!("{method} precond=none"));
    }
}

/// The point of the tier, checked end-to-end on the anisotropic
/// variable-coefficient problem: diagonal-aware preconditioning reaches
/// the tolerance in fewer iterations than plain CG.
#[test]
fn preconditioned_cg_cuts_iterations_on_aniso_problem() {
    let grid = Grid3::new(8, 8, 16);
    let eps_opts = SolveOpts {
        eps: 1e-8,
        ..SolveOpts::default()
    };
    let mut pb = Problem::build_aniso(grid, StencilKind::P7, 2);
    let plain = pb.solve(Method::parse("cg").unwrap(), &eps_opts, &mut Native);
    assert!(plain.converged, "plain cg: rel={}", plain.rel_residual);
    for (precond, inner) in [(PrecondKind::BlockJacobi, 2), (PrecondKind::Chebyshev, 4)] {
        let opts = SolveOpts {
            precond,
            inner_iters: inner,
            ..eps_opts.clone()
        };
        let mut pb = Problem::build_aniso(grid, StencilKind::P7, 2);
        let got = pb.solve(Method::parse("cg").unwrap(), &opts, &mut Native);
        assert!(got.converged, "{}: rel={}", precond.name(), got.rel_residual);
        assert!(got.x_error < 1e-5, "{}: x_err={}", precond.name(), got.x_error);
        assert!(
            got.iterations < plain.iterations,
            "{}: {} iters vs plain {}",
            precond.name(),
            got.iterations,
            plain.iterations
        );
    }
}

#[test]
fn executor_threads_scale_spmv_correctly_not_just_fast() {
    // sanity on a larger grid: many chunks, all strategies still bitwise
    // equal (this is the shape the benches measure for speedup).
    let sys = LocalSystem::build(Grid3::new(16, 16, 32), StencilKind::P7, 0, 1);
    let n = sys.n();
    let mut rng = Rng::new(3);
    let mut x = sys.new_ext();
    for v in x.iter_mut().take(n) {
        *v = rng.normal();
    }
    let mut want = vec![0.0; n];
    kernels::spmv_ell(&sys.a, &x, &mut want, 0, n);
    let opts = SolveOpts::default();
    for (exec, name) in executors(256) {
        assert!(
            exec.blocks(n, usize::MAX).len() > 8,
            "{name}: expected many chunks"
        );
        let mut backend = Native;
        let mut o = ops(&exec, &opts, &mut backend);
        let mut y = vec![0.0; n];
        o.spmv(&sys.a, &x, &mut y);
        assert_eq!(y, want, "{name}");
    }
}
